//! Runtime ISA detection and kernel dispatch — the one place the crate
//! decides which machine kernels the hot paths run.
//!
//! Two orthogonal decisions live here (they used to be scattered between
//! `gemm.rs` statics and a ~1 ms timing calibration):
//!
//! * **ISA** ([`table`]): detected once per process. On x86_64 with
//!   AVX2+FMA the packed GEMM path runs the explicit 4x8 intrinsic
//!   microkernel ([`micro_4x8_avx2fma`]) and the routing dot runs the
//!   two-chain AVX kernel; on aarch64 the NEON variants run; anywhere
//!   else the portable auto-vectorized tile and the scalar lane-striped
//!   dot are the fallback. The table is a set of function pointers, so
//!   `gemm`, `gemm_tn`/`gemm_nt`, and the tree-descent routing share one
//!   detection story and benches can label rows with [`KernelTable::isa`].
//! * **GEMM kind** ([`active`]): which execution strategy `gemm_acc`
//!   uses above the FLOP threshold — `packed` (panel packing + the
//!   microkernel from the table), `banded` (the iteration-1 `i-k-j`
//!   kernel per row band), or `serial` (the seed kernel, no pool).
//!   `FFF_GEMM_KERNEL=packed|banded|serial` overrides; tests re-enter
//!   dispatch per case via [`force`]. The old timing calibration is
//!   gone: with the microkernel written in intrinsics, packed wins on
//!   both gcc-style and LLVM codegen (EXPERIMENTS.md §Perf iteration 3),
//!   so the only reason to calibrate — auto-vectorizer variance — no
//!   longer exists.
//!
//! Numerics contracts (what the golden-vector fixtures pin):
//!
//! * The 4x8 microkernel accumulates `acc[r][j] = fma(a_r, b_j, acc[r][j])`
//!   with `p` ascending, then adds the tile into `C` with a separate add.
//!   [`micro_4x8_ref`] is the scalar `f32::mul_add` replica of exactly
//!   that order; the AVX2/FMA and NEON kernels are bit-identical to it.
//!   The portable tile uses separate multiply+add (unfused — what
//!   auto-vectorizers reliably emit), so fused and portable results may
//!   differ by final-rounding ulps; *within* one kernel, results are
//!   bit-identical across band splits and thread counts.
//! * The `_epi` microkernel variants fuse a store-phase [`Epilogue`]
//!   (bias add, bias+ReLU) into the tile writeback: each element stores
//!   `epi(C + acc)`, the same per-element operation order as a separate
//!   elementwise pass over a finished GEMM — so fused and unfused
//!   drivers are bit-identical kind by kind, and [`Epilogue::None`]
//!   degenerates to the base kernels exactly. The ReLU is the masked
//!   select [`relu_store`] (`-0.0`/NaN normalize to `+0.0` on every
//!   ISA; NEON deliberately avoids `vmaxq`, which would propagate NaN).
//! * [`routing_dot`] accumulates into 16 independent lanes
//!   (`lane = p mod 16`, separate mul and add, never FMA) reduced by a
//!   fixed pairwise tree. Every ISA performs the same IEEE operations in
//!   the same order, so routing decisions are bit-identical across x86,
//!   aarch64, and the scalar fallback — the invariant tree descent rides
//!   on (a logit on the wrong side of zero would route to a different
//!   leaf on different hardware).
//! * The int8 tile kernels ([`tile_i8_scalar`] and its SIMD twins in
//!   [`I8Kernels`]) accumulate quantized products in i32 — *exact*
//!   integer arithmetic, so unlike the f32 tiles every implementation
//!   and every accumulation order produces identical bits. A-side bytes
//!   are stored **biased**: `byte = q + 127` (u8 in `0..=254`, see
//!   [`quantize_row_q8_scalar`]), which lets AVX-VNNI's `vpdpbusd`
//!   consume them directly (u8×i8) and subtract the per-column
//!   correction `127·Σb` (the `corr` table `QuantPackedB` precomputes at
//!   quantize time) — still exact in i32. The maddubs kernel unbiases in-register
//!   (`psubb 127`) instead; the scalar replica unbiases per element.
//!   The one float stage is the fused dequantizing store:
//!   `(acc as f32) * (sa*sb) + bias[j]` (then the ReLU select), all
//!   plain mul/add (never `mul_add`), one written-out scalar statement
//!   every tile replicates — which is why int8 serving results are
//!   bit-identical across thread counts, bucket splits, and forced
//!   kernel kinds.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Microkernel tile: MR rows of `A` × NR columns of `B`.
pub const MR: usize = 4;
pub const NR: usize = 8;

/// Int8 packing group: QK consecutive `k` bytes per row/column — the
/// unit one 32-bit SIMD lane consumes (`vpmaddubsw`+`vpmaddwd`, or
/// `vpdpbusd` on AVX-VNNI). Packed int8 panels zero-pad `k` up to a
/// multiple of QK.
pub const QK: usize = 4;

/// Store-phase epilogue of the `_epi` microkernels and the band kernels'
/// write-back: each output element is stored as `C = epi(C + acc)`.
///
/// Numerics contract (what the epilogue golden vectors pin): the bias is
/// added *after* the accumulated tile is added into `C` — per element
/// `(C_partial + acc) + bias[j]` — which is exactly the order a separate
/// bias pass over a finished GEMM produces, so a fused store is
/// bit-identical to `gemm` + elementwise pass for every kernel kind and
/// thread count. The ReLU is [`relu_store`].
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// Plain accumulate store: `C += acc`.
    None,
    /// `C = (C + acc) + bias[j]`, bias broadcast over rows.
    Bias(&'a [f32]),
    /// `C = relu_store((C + acc) + bias[j])`.
    BiasRelu(&'a [f32]),
}

impl<'a> Epilogue<'a> {
    /// The epilogue restricted to columns `j0..` (for a column panel).
    #[inline]
    pub fn narrow(self, j0: usize) -> Epilogue<'a> {
        match self {
            Epilogue::None => Epilogue::None,
            Epilogue::Bias(b) => Epilogue::Bias(&b[j0..]),
            Epilogue::BiasRelu(b) => Epilogue::BiasRelu(&b[j0..]),
        }
    }

    /// Scalar application to one stored element — the single written-out
    /// statement of the epilogue every ISA's store phase replicates.
    #[inline]
    pub fn apply(self, j: usize, t: f32) -> f32 {
        match self {
            Epilogue::None => t,
            Epilogue::Bias(b) => t + b[j],
            Epilogue::BiasRelu(b) => relu_store(t + b[j]),
        }
    }

    /// Bias slice length available from column 0 (usize::MAX for `None`),
    /// for the entry-point bounds asserts.
    #[inline]
    fn bias_len(&self) -> usize {
        match self {
            Epilogue::None => usize::MAX,
            Epilogue::Bias(b) | Epilogue::BiasRelu(b) => b.len(),
        }
    }
}

/// The store-phase ReLU: strict `t > 0` keeps `t`, everything else stores
/// a literal `+0.0` — the same compare+mask select the SIMD kernels use,
/// so `-0.0` (and NaN) normalize to `+0.0` identically on every ISA.
#[inline]
pub fn relu_store(t: f32) -> f32 {
    if t > 0.0 {
        t
    } else {
        0.0
    }
}

/// GEMM execution strategy above the FLOP threshold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Panel packing + the ISA microkernel from [`table`], row bands on
    /// the pool.
    Packed,
    /// The iteration-1 `i-k-j` kernel per row band on the pool.
    Banded,
    /// The seed serial kernel, no pool dispatch at any size.
    Serial,
}

impl KernelKind {
    /// Every kind, in forced-test-matrix order.
    pub const ALL: [KernelKind; 3] = [KernelKind::Packed, KernelKind::Banded, KernelKind::Serial];

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Packed => "packed",
            KernelKind::Banded => "banded",
            KernelKind::Serial => "serial",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "packed" => Some(KernelKind::Packed),
            "banded" => Some(KernelKind::Banded),
            "serial" => Some(KernelKind::Serial),
            _ => None,
        }
    }
}

/// Programmatic override (0 = none, else kind discriminant + 1).
static FORCED: AtomicU8 = AtomicU8::new(0);

/// The GEMM kind the dispatcher uses *now*: [`force`] override first,
/// then `FFF_GEMM_KERNEL` (read once per process), then `packed`.
pub fn active() -> KernelKind {
    match FORCED.load(Ordering::Relaxed) {
        1 => KernelKind::Packed,
        2 => KernelKind::Banded,
        3 => KernelKind::Serial,
        _ => env_default(),
    }
}

/// Force (or clear) the GEMM kind for subsequent dispatches. This is the
/// re-entry point of the forced-kernel test matrix
/// ([`crate::testing::check_kernels`]): unlike the env override it can
/// change per test case within one process. Forcing sections that assert
/// on [`active`] should hold [`force_lock`] — the override is
/// process-global and `cargo test` runs tests on concurrent threads.
pub fn force(kind: Option<KernelKind>) {
    FORCED.store(kind.map(|k| k as u8 + 1).unwrap_or(0), Ordering::Relaxed);
}

/// Serializes forcing sections against each other (see [`force`]).
pub fn force_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn env_default() -> KernelKind {
    static ENV: OnceLock<KernelKind> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("FFF_GEMM_KERNEL") {
        Ok(v) => KernelKind::parse(&v).unwrap_or_else(|| {
            eprintln!("FFF_GEMM_KERNEL: unknown kernel {v:?} (want packed|banded|serial); using packed");
            KernelKind::Packed
        }),
        // Under Miri the default kind is the scalar serial path (the
        // cfg(miri) shim — EXPERIMENTS.md §Analysis); forced-kernel
        // tests still exercise the packed drivers explicitly.
        Err(_) if cfg!(miri) => KernelKind::Serial,
        Err(_) => KernelKind::Packed,
    })
}

/// Serving precision of a compiled inference engine.
///
/// `F32` is the default and the accuracy oracle; `Int8` runs the leaf
/// GEMMs over symmetric per-panel-quantized weights with i32
/// accumulation — a weight-bandwidth play (EXPERIMENTS.md §Perf
/// iteration 6). Routing and training stay f32 regardless: only the
/// bucketed leaf GEMMs change representation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Precision {
    /// f32 weights and arithmetic — the default and the oracle.
    F32,
    /// int8 symmetric per-panel weights, i32 accumulation, dequantizing
    /// epilogue store.
    Int8,
}

impl Precision {
    /// Every precision, in sweep order.
    pub const ALL: [Precision; 2] = [Precision::F32, Precision::Int8];

    pub fn name(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }

    pub fn parse(s: &str) -> Option<Precision> {
        match s {
            "f32" => Some(Precision::F32),
            "int8" => Some(Precision::Int8),
            _ => None,
        }
    }
}

/// The `FFF_PRECISION` process override (read once): `Some(p)` forces
/// every subsequent inference compile to precision `p`, overriding the
/// compile option and serve config alike; unset leaves them alone.
pub fn precision_override() -> Option<Precision> {
    static ENV: OnceLock<Option<Precision>> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("FFF_PRECISION") {
        Ok(v) => {
            let p = Precision::parse(&v);
            if p.is_none() {
                eprintln!("FFF_PRECISION: unknown precision {v:?} (want f32|int8); ignoring");
            }
            p
        }
        Err(_) => None,
    })
}

/// The precision a compile requesting `requested` actually gets:
/// [`precision_override`] wins, otherwise the request stands.
pub fn resolve_precision(requested: Precision) -> Precision {
    precision_override().unwrap_or(requested)
}

/// The `FFF_PARALLEL` process override (read once): `Some(p)` forces
/// every subsequent env-resolving model construction to `p` parallel
/// trees (UltraFastBERT-style `parallel_size`), overriding config and
/// CLI alike; unset leaves them alone. `p` must be ≥ 1.
pub fn parallel_override() -> Option<usize> {
    static ENV: OnceLock<Option<usize>> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("FFF_PARALLEL") {
        Ok(v) => {
            let p = v.parse::<usize>().ok().filter(|&p| p >= 1);
            if p.is_none() {
                eprintln!("FFF_PARALLEL: invalid tree count {v:?} (want an integer >= 1); ignored");
            }
            p
        }
        Err(_) => None,
    })
}

/// The parallel-tree count a construction requesting `requested` trees
/// actually gets: [`parallel_override`] wins, otherwise the request
/// stands (mirrors [`resolve_precision`]).
pub fn resolve_parallel(requested: usize) -> usize {
    parallel_override().unwrap_or(requested.max(1))
}

/// `C[mr×nr] += A-panel · B-panel` over packed panels: `ap` is `kc`
/// MR-groups (zero-padded), `bp` is `kc` NR-groups (zero-padded), `cv`
/// starts at the tile's top-left element with row stride `n`.
pub type Micro4x8 =
    fn(kc: usize, ap: &[f32], bp: &[f32], cv: &mut [f32], n: usize, mr: usize, nr: usize);

/// [`Micro4x8`] with a fused store-phase [`Epilogue`]: the tile is stored
/// as `C = epi(C + acc)` instead of `C += acc`, saving the separate
/// bias/ReLU pass over `C` (which at leaf-GEMM shapes — small `k`, wide
/// `n` — costs as much as the accumulation itself).
pub type Micro4x8Epi = for<'a> fn(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue<'a>,
);

/// The biased-zero A-side byte: A rows quantize as `byte = q + 127`
/// (u8 in `0..=254`), so a quantized zero — including every `k`-tail pad
/// byte — stores as 127. B-side panel bytes stay plain signed i8.
pub const QA_ZERO: u8 = 127;

/// Per-row A-side quantization into biased-u8 bytes; returns the row's
/// symmetric scale. Every entry is bit-identical to
/// [`quantize_row_q8_scalar`] (same statement per element, and the
/// absmax reduction is a pure `max` tree — order-insensitive).
pub type QuantRowQ8 = fn(v: &[f32], q: &mut [u8]) -> f32;

/// Fused int8 tile: MR×NR i32 kernel over one B panel plus the
/// dequantizing epilogue store, scattered by per-row output offsets.
///
/// `ap` points at MR contiguous biased-u8 A rows (`astride` bytes apart,
/// the first `kg*QK` of each used — pad rows beyond `mr` are read but
/// never stored); `bp`/`corr`/`sb` are one `QuantPackedB` panel, its
/// `127·Σb` correction row, and its scale; `sa` holds the `mr` row
/// scales; `bias` points at ≥ NR floats for this panel's columns (the
/// drivers substitute a zero array for [`Epilogue::None`]); row `r < mr`
/// stores `NR` floats at `cp + roff[r]`.
///
/// # Safety
/// All pointers must cover the extents above; `cp + roff[r] .. + NR`
/// must be in bounds and unaliased for each stored row; SIMD entries
/// additionally require their detected ISA (guaranteed by dispatch).
pub type TileI8 = unsafe fn(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp: *const i8,
    corr: *const i32,
    sa: *const f32,
    sb: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
);

/// Two-panel fused int8 tile (MR × 2·NR): shares each A broadcast
/// across both B panels; the two panels keep independent accumulators,
/// so the i32 order — and therefore every bit — matches two single-panel
/// tiles. `bias` points at ≥ 2·NR floats; row `r` stores `2·NR` floats
/// at `cp + roff[r]`. Safety as [`TileI8`].
pub type TileI8X2 = unsafe fn(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp0: *const i8,
    bp1: *const i8,
    corr0: *const i32,
    corr1: *const i32,
    sa: *const f32,
    sb0: f32,
    sb1: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
);

/// Register-fused leaf tile (`ell == 2·NR` only): the two-panel kernel
/// plus an in-register bias+ReLU **requantize** epilogue. A finished L1
/// output row is exactly two ymm registers, so each row is dequantized,
/// biased, ReLU'd, and requantized to biased-u8 (16 bytes stored at
/// `qdst + r*qstride`, scale at `sa_out[r]`) without ever touching
/// memory as f32. The requantize replicates the [`QuantRowQ8`]
/// statement exactly (the absmax is the true row max — a pure `max`
/// reduction — and the f32 store/load it skips is lossless), so bytes
/// and scale bits equal the unfused store-then-requantize path.
/// Safety as [`TileI8`], with `qdst`/`sa_out` in place of `cp`.
pub type TileI8Leaf = unsafe fn(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp0: *const i8,
    bp1: *const i8,
    corr0: *const i32,
    corr1: *const i32,
    sa: *const f32,
    sb0: f32,
    sb1: f32,
    bias: *const f32,
    qdst: *mut u8,
    qstride: usize,
    sa_out: *mut f32,
    mr: usize,
);

/// One int8 kernel set — the quantized serving path's dispatch unit.
/// Every set produces bit-identical results (exact i32 accumulation +
/// one shared store statement); they differ only in speed.
pub struct I8Kernels {
    /// `avx-vnni`, `avx2-maddubs`, or `scalar-i32` (bench labels).
    pub label: &'static str,
    /// The A-row quantizer (SIMD where detected).
    pub quant_row: QuantRowQ8,
    /// Full-width fused tile.
    pub tile: TileI8,
    /// Two-panel fused tile; `None` makes the drivers loop singles.
    pub tile_x2: Option<TileI8X2>,
    /// Register-fused leaf tile; `None` makes the leaf engine take the
    /// unfused two-GEMM path.
    pub tile_leaf: Option<TileI8Leaf>,
}

/// The scalar int8 kernel set — the written-out statement of the
/// quantized numerics and the fallback everywhere SIMD isn't detected
/// (or a non-`packed` kind is forced).
pub static I8_SCALAR: I8Kernels = I8Kernels {
    label: "scalar-i32",
    quant_row: quantize_row_q8_scalar,
    tile: tile_i8_scalar_entry,
    tile_x2: None,
    tile_leaf: None,
};

/// The int8 kernel set the current GEMM kind dispatches to: the detected
/// SIMD set for `packed`, the scalar replica for `banded`/`serial` —
/// bit-identical either way, so forcing a kind changes speed, never
/// results.
pub fn active_i8() -> &'static I8Kernels {
    if active() == KernelKind::Packed {
        table().i8k
    } else {
        &I8_SCALAR
    }
}

/// The boundary-logit dot product (lane-striped, fixed reduction).
pub type RoutingDotFn = fn(&[f32], &[f32]) -> f32;

/// The per-process kernel set, selected by runtime CPU detection.
pub struct KernelTable {
    /// Detected ISA label for bench rows / diagnostics:
    /// `avx2-fma`, `avx`, `neon`, or `portable`.
    pub isa: &'static str,
    /// Whether [`KernelTable::micro_4x8`] uses fused multiply-add (and is
    /// therefore bit-identical to [`micro_4x8_ref`] rather than to the
    /// portable tile).
    pub fused_tile: bool,
    /// The packed-path GEMM microkernel.
    pub micro_4x8: Micro4x8,
    /// The epilogue-fusing variant of the microkernel; with
    /// [`Epilogue::None`] it is bit-identical to [`KernelTable::micro_4x8`]
    /// (the base kernels are thin `None` wrappers around it).
    pub micro_4x8_epi: Micro4x8Epi,
    /// The tree-descent dot kernel (always ≡ [`routing_dot_scalar`]).
    pub routing_dot: RoutingDotFn,
    /// The detected int8 kernel set (`maddubs`+`madd` on AVX2,
    /// `vpdpbusd` where AVX-VNNI is detected, the scalar i32 replica
    /// elsewhere); always bit-identical to [`I8_SCALAR`]. Dispatch goes
    /// through [`active_i8`], which falls back to the scalar set when a
    /// non-`packed` kind is forced.
    pub i8k: &'static I8Kernels,
}

/// The detected kernel table (runs CPU feature detection on first call).
pub fn table() -> &'static KernelTable {
    static TABLE: OnceLock<KernelTable> = OnceLock::new();
    TABLE.get_or_init(detect)
}

fn detect() -> KernelTable {
    // Miri cannot execute `target_feature` intrinsics, so detection
    // short-circuits to the portable table there — every kernel the
    // interpreter runs is plain safe-or-audited Rust, while the
    // dispatch/packing drivers above the table stay fully exercised.
    if cfg!(miri) {
        return KernelTable {
            isa: "portable",
            fused_tile: false,
            micro_4x8: micro_4x8_portable,
            micro_4x8_epi: micro_4x8_portable_epi,
            routing_dot: routing_dot_scalar,
            i8k: &I8_SCALAR,
        };
    }
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            // The int8 kernels need only avx2; vpdpbusd consumes the
            // biased-u8 A bytes directly (corr-subtracted) where
            // AVX-VNNI is present.
            let i8k: &'static I8Kernels = if std::arch::is_x86_feature_detected!("avxvnni") {
                &I8_VNNI
            } else {
                &I8_MADDUBS
            };
            return KernelTable {
                isa: "avx2-fma",
                fused_tile: true,
                micro_4x8: micro_4x8_avx2fma_entry,
                micro_4x8_epi: micro_4x8_epi_avx2fma_entry,
                routing_dot: routing_dot_avx_entry,
                i8k,
            };
        }
        if std::arch::is_x86_feature_detected!("avx") {
            // AVX without FMA: the routing dot still gets its two 8-wide
            // chains; the GEMM tile stays on the portable (unfused) form
            // and the int8 path on the scalar replica (maddubs is avx2).
            return KernelTable {
                isa: "avx",
                fused_tile: false,
                micro_4x8: micro_4x8_portable,
                micro_4x8_epi: micro_4x8_portable_epi,
                routing_dot: routing_dot_avx_entry,
                i8k: &I8_SCALAR,
            };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return KernelTable {
                isa: "neon",
                fused_tile: true,
                micro_4x8: micro_4x8_neon_entry,
                micro_4x8_epi: micro_4x8_epi_neon_entry,
                routing_dot: routing_dot_neon_entry,
                i8k: &I8_SCALAR,
            };
        }
    }
    KernelTable {
        isa: "portable",
        fused_tile: false,
        micro_4x8: micro_4x8_portable,
        micro_4x8_epi: micro_4x8_portable_epi,
        routing_dot: routing_dot_scalar,
        i8k: &I8_SCALAR,
    }
}

// ---------------------------------------------------------------------------
// 4x8 GEMM microkernels.
// ---------------------------------------------------------------------------

/// Scalar `f32::mul_add` replica of the fused microkernel contract —
/// the documented accumulation order the AVX2/FMA and NEON kernels are
/// bit-identical to. Slow; exists for golden-vector fixtures and as the
/// single written-out statement of the tile numerics.
pub fn micro_4x8_ref(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
) {
    micro_4x8_ref_epi(kc, ap, bp, cv, n, mr, nr, Epilogue::None)
}

/// [`micro_4x8_ref`] with the fused store-phase epilogue — the scalar
/// `mul_add` contract the AVX2/FMA and NEON `_epi` kernels are
/// bit-identical to. With [`Epilogue::None`] the store degenerates to
/// `C += acc`, so this is also the implementation behind
/// [`micro_4x8_ref`].
pub fn micro_4x8_ref_epi(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let a: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        let b: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        for (r, row) in acc.iter_mut().enumerate() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = a[r].mul_add(b[j], *slot);
            }
        }
    }
    for r in 0..mr {
        for j in 0..nr {
            cv[r * n + j] = epi.apply(j, cv[r * n + j] + acc[r][j]);
        }
    }
}

/// The portable 4x8 tile: separate multiply+add in a shape LLVM's
/// auto-vectorizer reliably widens (the `matrixmultiply` idiom). The
/// fallback where no intrinsic kernel is installed.
///
/// Accumulators are four `[f32; NR]` arrays whose addresses are never
/// taken, so the compiler can keep the tile in SIMD registers (the
/// prototype showed that forming pointers into them forces a stack
/// spill — EXPERIMENTS.md §Perf, microkernel lesson #1).
pub fn micro_4x8_portable(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc0 = [0.0f32; NR];
    let mut acc1 = [0.0f32; NR];
    let mut acc2 = [0.0f32; NR];
    let mut acc3 = [0.0f32; NR];
    for p in 0..kc {
        let b: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        let a: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        for (acc, &bc) in acc0.iter_mut().zip(b.iter()) {
            *acc += a[0] * bc;
        }
        for (acc, &bc) in acc1.iter_mut().zip(b.iter()) {
            *acc += a[1] * bc;
        }
        for (acc, &bc) in acc2.iter_mut().zip(b.iter()) {
            *acc += a[2] * bc;
        }
        for (acc, &bc) in acc3.iter_mut().zip(b.iter()) {
            *acc += a[3] * bc;
        }
    }
    if mr > 0 {
        for (cj, &s) in cv[..nr].iter_mut().zip(acc0.iter()) {
            *cj += s;
        }
    }
    if mr > 1 {
        for (cj, &s) in cv[n..n + nr].iter_mut().zip(acc1.iter()) {
            *cj += s;
        }
    }
    if mr > 2 {
        for (cj, &s) in cv[2 * n..2 * n + nr].iter_mut().zip(acc2.iter()) {
            *cj += s;
        }
    }
    if mr > 3 {
        for (cj, &s) in cv[3 * n..3 * n + nr].iter_mut().zip(acc3.iter()) {
            *cj += s;
        }
    }
}

/// [`micro_4x8_portable`] with the fused store-phase epilogue: the same
/// unfused mul+add accumulation loop, then `C = epi(C + acc)` in one
/// pass while the tile is still in registers. [`Epilogue::None`] routes
/// to the base tile (identical stores either way).
pub fn micro_4x8_portable_epi(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    if matches!(epi, Epilogue::None) {
        return micro_4x8_portable(kc, ap, bp, cv, n, mr, nr);
    }
    let mut acc0 = [0.0f32; NR];
    let mut acc1 = [0.0f32; NR];
    let mut acc2 = [0.0f32; NR];
    let mut acc3 = [0.0f32; NR];
    for p in 0..kc {
        let b: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        let a: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        for (acc, &bc) in acc0.iter_mut().zip(b.iter()) {
            *acc += a[0] * bc;
        }
        for (acc, &bc) in acc1.iter_mut().zip(b.iter()) {
            *acc += a[1] * bc;
        }
        for (acc, &bc) in acc2.iter_mut().zip(b.iter()) {
            *acc += a[2] * bc;
        }
        for (acc, &bc) in acc3.iter_mut().zip(b.iter()) {
            *acc += a[3] * bc;
        }
    }
    // Spilling the accumulators into one array here is fine: the hot
    // kc loop above never took their addresses.
    let accs = [acc0, acc1, acc2, acc3];
    for (r, acc) in accs.iter().enumerate().take(mr) {
        for (j, &s) in acc.iter().enumerate().take(nr) {
            cv[r * n + j] = epi.apply(j, cv[r * n + j] + s);
        }
    }
}

/// Table entry for the AVX2/FMA kernel.
#[cfg(target_arch = "x86_64")]
fn micro_4x8_avx2fma_entry(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
) {
    micro_4x8_epi_avx2fma_entry(kc, ap, bp, cv, n, mr, nr, Epilogue::None)
}

/// Table entry for the AVX2/FMA kernel with fused epilogue.
#[cfg(target_arch = "x86_64")]
fn micro_4x8_epi_avx2fma_entry(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    // Real asserts, not debug: the table field is `pub`, so safe code can
    // reach this with short panels, and the kernel reads through raw
    // pointers. One branch per tile is noise next to a kc-deep FMA loop.
    assert!(ap.len() >= kc * MR && bp.len() >= kc * NR, "micro_4x8: short panel");
    assert!(mr == 0 || cv.len() >= (mr - 1) * n + nr, "micro_4x8: short C tile");
    // Full-width epilogue tiles load 8 bias lanes with one vector read.
    assert!(epi.bias_len() >= nr, "micro_4x8: short bias");
    // SAFETY: installed in the table only after runtime avx2+fma
    // detection; panel/tile/bias bounds asserted above.
    unsafe { micro_4x8_avx2fma(kc, ap, bp, cv, n, mr, nr, epi) }
}

/// Explicit 4x8 AVX2/FMA microkernel: per `p`, one 8-wide load of the
/// `B` group and four broadcast+FMA updates; the tile lives in four ymm
/// registers for the whole `kc` loop. Bit-identical to
/// [`micro_4x8_ref`]. Measured 62.8/65.6 GF/s serial at 256³/512³ under
/// the compiler whose auto-vectorized tile ran at 11.7 GF/s
/// (EXPERIMENTS.md §Perf iteration 3).
///
/// # Safety
///
/// avx2+fma must be runtime-verified by the caller, `ap`/`bp` must hold
/// `kc` full MR-/NR-groups, and `cv` must cover the `mr`-row tile at
/// stride `n` — the `_entry` wrapper asserts all of this.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn micro_4x8_avx2fma(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    // SAFETY: caller contract: avx2+fma are present and `ap`/`bp` hold `kc`
    // full MR-/NR-groups while `cv` covers the `mr`-row tile at stride
    // `n` — the `*_entry` wrapper asserts all of this before delegating.
    // Every pointer formed below stays inside those slices.
    unsafe {
        use std::arch::x86_64::{
            _mm256_add_ps, _mm256_and_ps, _mm256_broadcast_ss, _mm256_cmp_ps, _mm256_fmadd_ps,
            _mm256_loadu_ps, _mm256_setzero_ps, _mm256_storeu_ps, _CMP_GT_OQ,
        };
        let apt = ap.as_ptr();
        let bpt = bp.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        for p in 0..kc {
            let b = _mm256_loadu_ps(bpt.add(p * NR));
            let a = apt.add(p * MR);
            acc0 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a), b, acc0);
            acc1 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a.add(1)), b, acc1);
            acc2 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a.add(2)), b, acc2);
            acc3 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a.add(3)), b, acc3);
        }
        if nr == NR {
            // Full-width tile: vector read-modify-write per C row, with the
            // epilogue fused into the same store. The ReLU select is
            // `and(t, t > 0)` — bit-identical to [`relu_store`] (NaN and
            // -0.0 both mask to +0.0).
            let c = cv.as_mut_ptr();
            let zero = _mm256_setzero_ps();
            let (bias, relu, fused) = match epi {
                Epilogue::None => (zero, false, false),
                Epilogue::Bias(b) => (_mm256_loadu_ps(b.as_ptr()), false, true),
                Epilogue::BiasRelu(b) => (_mm256_loadu_ps(b.as_ptr()), true, true),
            };
            macro_rules! store_row {
                ($off:expr, $acc:expr) => {{
                    let cr = c.add($off);
                    let mut t = _mm256_add_ps(_mm256_loadu_ps(cr), $acc);
                    if fused {
                        t = _mm256_add_ps(t, bias);
                    }
                    if relu {
                        t = _mm256_and_ps(t, _mm256_cmp_ps::<_CMP_GT_OQ>(t, zero));
                    }
                    _mm256_storeu_ps(cr, t);
                }};
            }
            if mr > 0 {
                store_row!(0, acc0);
            }
            if mr > 1 {
                store_row!(n, acc1);
            }
            if mr > 2 {
                store_row!(2 * n, acc2);
            }
            if mr > 3 {
                store_row!(3 * n, acc3);
            }
        } else {
            // Edge tile: spill the accumulators once, then masked scalar
            // writeback through the epilogue (the loop above never took
            // their address).
            let mut t = [[0.0f32; NR]; MR];
            _mm256_storeu_ps(t[0].as_mut_ptr(), acc0);
            _mm256_storeu_ps(t[1].as_mut_ptr(), acc1);
            _mm256_storeu_ps(t[2].as_mut_ptr(), acc2);
            _mm256_storeu_ps(t[3].as_mut_ptr(), acc3);
            for (r, row) in t.iter().enumerate().take(mr) {
                for (j, &s) in row.iter().enumerate().take(nr) {
                    cv[r * n + j] = epi.apply(j, cv[r * n + j] + s);
                }
            }
        }
    }
}

/// Table entry for the NEON kernel.
#[cfg(target_arch = "aarch64")]
fn micro_4x8_neon_entry(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
) {
    micro_4x8_epi_neon_entry(kc, ap, bp, cv, n, mr, nr, Epilogue::None)
}

/// Table entry for the NEON kernel with fused epilogue.
#[cfg(target_arch = "aarch64")]
fn micro_4x8_epi_neon_entry(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    // Real asserts, not debug — see micro_4x8_epi_avx2fma_entry.
    assert!(ap.len() >= kc * MR && bp.len() >= kc * NR, "micro_4x8: short panel");
    assert!(mr == 0 || cv.len() >= (mr - 1) * n + nr, "micro_4x8: short C tile");
    assert!(epi.bias_len() >= nr, "micro_4x8: short bias");
    // SAFETY: installed in the table only after runtime neon detection;
    // panel/tile/bias bounds asserted above.
    unsafe { micro_4x8_neon(kc, ap, bp, cv, n, mr, nr, epi) }
}

/// NEON 4x4 microkernel, applied to each 4-column half of the packed
/// 8-wide `B` panel: per `p`, two 4-wide loads of the `B` group and four
/// `vfmaq` updates per half (eight q-register accumulators total). Lane
/// `j` accumulates `fma(a_r, b_j, acc)` with `p` ascending — the same
/// per-lane order as the AVX2 kernel — so NEON output is bit-identical
/// to [`micro_4x8_ref`] too.
///
/// # Safety
///
/// neon must be runtime-verified by the caller, `ap`/`bp` must hold
/// `kc` full MR-/NR-groups, and `cv` must cover the `mr`-row tile at
/// stride `n` — the `_entry` wrapper asserts all of this.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn micro_4x8_neon(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    // SAFETY: caller contract: neon is present and `ap`/`bp` hold `kc` full
    // MR-/NR-groups while `cv` covers the `mr`-row tile at stride `n` —
    // the `*_entry` wrapper asserts all of this before delegating. Every
    // pointer formed below stays inside those slices.
    unsafe {
        use std::arch::aarch64::{
            vaddq_f32, vandq_u32, vcgtq_f32, vdupq_n_f32, vfmaq_f32, vld1q_f32,
            vreinterpretq_f32_u32, vreinterpretq_u32_f32, vst1q_f32,
        };
        let apt = ap.as_ptr();
        let bpt = bp.as_ptr();
        // acc{r}l = lanes 0..4 of row r, acc{r}h = lanes 4..8.
        let mut acc0l = vdupq_n_f32(0.0);
        let mut acc0h = vdupq_n_f32(0.0);
        let mut acc1l = vdupq_n_f32(0.0);
        let mut acc1h = vdupq_n_f32(0.0);
        let mut acc2l = vdupq_n_f32(0.0);
        let mut acc2h = vdupq_n_f32(0.0);
        let mut acc3l = vdupq_n_f32(0.0);
        let mut acc3h = vdupq_n_f32(0.0);
        for p in 0..kc {
            let bl = vld1q_f32(bpt.add(p * NR));
            let bh = vld1q_f32(bpt.add(p * NR + 4));
            let a = apt.add(p * MR);
            let a0 = vdupq_n_f32(*a);
            let a1 = vdupq_n_f32(*a.add(1));
            let a2 = vdupq_n_f32(*a.add(2));
            let a3 = vdupq_n_f32(*a.add(3));
            acc0l = vfmaq_f32(acc0l, a0, bl);
            acc0h = vfmaq_f32(acc0h, a0, bh);
            acc1l = vfmaq_f32(acc1l, a1, bl);
            acc1h = vfmaq_f32(acc1h, a1, bh);
            acc2l = vfmaq_f32(acc2l, a2, bl);
            acc2h = vfmaq_f32(acc2h, a2, bh);
            acc3l = vfmaq_f32(acc3l, a3, bl);
            acc3h = vfmaq_f32(acc3h, a3, bh);
        }
        if nr == NR {
            let c = cv.as_mut_ptr();
            let zero = vdupq_n_f32(0.0);
            let (biasl, biash, relu, fused) = match epi {
                Epilogue::None => (zero, zero, false, false),
                Epilogue::Bias(b) => {
                    (vld1q_f32(b.as_ptr()), vld1q_f32(b.as_ptr().add(4)), false, true)
                }
                Epilogue::BiasRelu(b) => {
                    (vld1q_f32(b.as_ptr()), vld1q_f32(b.as_ptr().add(4)), true, true)
                }
            };
            // The ReLU select is `and(t, t > 0)` (vcgtq mask), bit-identical
            // to [`relu_store`] — NEON's vmaxq would propagate NaN where x86
            // maxps and the scalar replica return +0.0, so the masked form is
            // the one that matches across ISAs.
            macro_rules! store_row {
                ($off:expr, $accl:expr, $acch:expr) => {{
                    let cr = c.add($off);
                    let mut tl = vaddq_f32(vld1q_f32(cr), $accl);
                    let mut th = vaddq_f32(vld1q_f32(cr.add(4)), $acch);
                    if fused {
                        tl = vaddq_f32(tl, biasl);
                        th = vaddq_f32(th, biash);
                    }
                    if relu {
                        tl = vreinterpretq_f32_u32(vandq_u32(
                            vreinterpretq_u32_f32(tl),
                            vcgtq_f32(tl, zero),
                        ));
                        th = vreinterpretq_f32_u32(vandq_u32(
                            vreinterpretq_u32_f32(th),
                            vcgtq_f32(th, zero),
                        ));
                    }
                    vst1q_f32(cr, tl);
                    vst1q_f32(cr.add(4), th);
                }};
            }
            if mr > 0 {
                store_row!(0, acc0l, acc0h);
            }
            if mr > 1 {
                store_row!(n, acc1l, acc1h);
            }
            if mr > 2 {
                store_row!(2 * n, acc2l, acc2h);
            }
            if mr > 3 {
                store_row!(3 * n, acc3l, acc3h);
            }
        } else {
            let mut t = [[0.0f32; NR]; MR];
            vst1q_f32(t[0].as_mut_ptr(), acc0l);
            vst1q_f32(t[0].as_mut_ptr().add(4), acc0h);
            vst1q_f32(t[1].as_mut_ptr(), acc1l);
            vst1q_f32(t[1].as_mut_ptr().add(4), acc1h);
            vst1q_f32(t[2].as_mut_ptr(), acc2l);
            vst1q_f32(t[2].as_mut_ptr().add(4), acc2h);
            vst1q_f32(t[3].as_mut_ptr(), acc3l);
            vst1q_f32(t[3].as_mut_ptr().add(4), acc3h);
            for (r, row) in t.iter().enumerate().take(mr) {
                for (j, &s) in row.iter().enumerate().take(nr) {
                    cv[r * n + j] = epi.apply(j, cv[r * n + j] + s);
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Int8 kernels (the quantized serving path): per-row quantize, fused
// dequantizing tiles, and the register-fused leaf tile.
// ---------------------------------------------------------------------------

/// Symmetric per-row quantization into **biased** u8 bytes: returns the
/// row's scale (`absmax / 127`, or `1.0` for an all-zero row — the
/// divide-by-zero guard the zero-row golden vectors pin) and writes
/// `byte = q + 127` into `q`, where the signed quantized value is
/// clamped to ±127. The biased range is `0..=254` (255 never appears),
/// a quantized zero is [`QA_ZERO`] = 127, and the underlying signed
/// value never reaches −128 — which is what keeps `vpmaddubsw`'s i16
/// pair sums saturation-free after unbiasing (2·127² = 32258 < 32767)
/// and lets `vpdpbusd` consume the biased bytes as its u8 operand.
///
/// The per-element statement is `trunc(clamp(x * (1/scale)) ± 0.5) + 127`
/// — multiply by the reciprocal, clamp in the float domain, then
/// round-half-away-from-zero spelled as `t + copysign(0.5, t)` followed
/// by a truncating cast. This is deliberate: `f32::round` is a libm
/// call per element that the autovectorizer cannot touch, and A-rows
/// are quantized on every serving pass (batch × dim elements), so the
/// naive `(x / scale).round()` form dominates the whole int8 pass
/// (measured ~3x slower end to end at dim 256). The copysign form is
/// branchless mul/min/max/add/cvtt and vectorizes cleanly. It agrees
/// with `round()` everywhere except the carry edge `t = k + (0.5 - ε)`
/// where `t + 0.5` rounds up — the quantizer's spec is this statement,
/// not libm's.
///
/// The one written-out statement of A-side quantization. The absmax
/// pass is a pure `max` reduction (no adds), so it is order-insensitive
/// and the SIMD variant's 4-accumulator sweep produces the same scale
/// bits; the per-element statement is elementwise IEEE, so the bytes
/// match too — every quantize path (scalar, AVX2, the register-fused
/// leaf epilogue) agrees exactly.
pub fn quantize_row_q8_scalar(v: &[f32], q: &mut [u8]) -> f32 {
    assert!(q.len() >= v.len(), "quantize_row_q8: short byte row");
    let mut absmax = 0.0f32;
    for &x in v {
        absmax = absmax.max(x.abs());
    }
    let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
    let inv = 1.0 / scale;
    for (qi, &x) in q.iter_mut().zip(v.iter()) {
        let t = (x * inv).clamp(-127.0, 127.0);
        *qi = (((t + 0.5f32.copysign(t)) as i32) + 127) as u8;
    }
    scale
}

/// [`QuantRowQ8`] entry for the AVX2 quantizer.
#[cfg(target_arch = "x86_64")]
fn quantize_row_q8_avx2_entry(v: &[f32], q: &mut [u8]) -> f32 {
    assert!(q.len() >= v.len(), "quantize_row_q8: short byte row");
    // SAFETY: installed in a kernel set only after runtime avx2
    // detection; byte bounds asserted above.
    unsafe { quantize_row_q8_avx2(v, q) }
}

/// AVX2 per-row quantizer: 4-accumulator absmax sweep (32 floats/iter),
/// then an 8-wide quantize loop packing 32/16/8 bytes per store.
///
/// Bit-identical to [`quantize_row_q8_scalar`]: the absmax is a pure
/// `max` reduction (order-insensitive), and mul / min / max /
/// copysign-add (`or(0.5, and(t, -0.0))`) / truncating convert are all
/// elementwise IEEE ops. When `absmax >= 1e-35` the wide loops skip the
/// ±127 clamp: a normal absmax bounds `|x|·inv ≤ 127·(1+2ε) < 127.5`,
/// so the clamp can never change a byte — the clamped loops below
/// remain the authoritative statement and guard denormal absmax, where
/// `inv` overflows to inf. The 16-byte packer is
/// `packs_epi32` (in-lane i16) → `packs_epi16` (in-lane i8) → bias
/// `+127` → `permutevar8x32(0,4,1,5,·)` to undo the lane interleave;
/// the 32-byte variant uses the full `(0,4,1,5,2,6,3,7)` permute.
///
/// # Safety
///
/// avx2 must be runtime-verified by the caller and `q` must hold at
/// least `v.len()` bytes — the `_entry` wrapper asserts both.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn quantize_row_q8_avx2(v: &[f32], q: &mut [u8]) -> f32 {
    // SAFETY: caller contract: avx2 is present and `q` holds at least `v.len()`
    // bytes (the entry asserts it); every load stays inside `v` and
    // every store inside `q` — the wide loops stop 32/16/8 short of `k`
    // and the scalar tail finishes element-wise.
    unsafe {
        use std::arch::x86_64::{
            __m128i, _mm256_add_epi8, _mm256_andnot_ps, _mm256_castps256_ps128,
            _mm256_castsi256_si128, _mm256_extractf128_ps, _mm256_extracti128_si256,
            _mm256_loadu_ps, _mm256_max_ps, _mm256_min_ps, _mm256_mul_ps, _mm256_packs_epi16,
            _mm256_packs_epi32, _mm256_permutevar8x32_epi32, _mm256_set1_epi8, _mm256_set1_ps,
            _mm256_setr_epi32, _mm256_setzero_ps, _mm256_storeu_si256, _mm_add_epi8, _mm_cvtss_f32,
            _mm_max_ps, _mm_max_ss, _mm_movehl_ps, _mm_packs_epi16, _mm_packs_epi32, _mm_set1_epi8,
            _mm_shuffle_ps, _mm_storel_epi64, _mm_storeu_si128,
        };
        let k = v.len();
        let vp = v.as_ptr();
        let dst = q.as_mut_ptr();
        let vsign = _mm256_set1_ps(-0.0);
        let mut am0 = _mm256_setzero_ps();
        let mut am1 = am0;
        let mut am2 = am0;
        let mut am3 = am0;
        let mut p = 0usize;
        while p + 32 <= k {
            am0 = _mm256_max_ps(am0, _mm256_andnot_ps(vsign, _mm256_loadu_ps(vp.add(p))));
            am1 = _mm256_max_ps(am1, _mm256_andnot_ps(vsign, _mm256_loadu_ps(vp.add(p + 8))));
            am2 = _mm256_max_ps(am2, _mm256_andnot_ps(vsign, _mm256_loadu_ps(vp.add(p + 16))));
            am3 = _mm256_max_ps(am3, _mm256_andnot_ps(vsign, _mm256_loadu_ps(vp.add(p + 24))));
            p += 32;
        }
        while p + 8 <= k {
            am0 = _mm256_max_ps(am0, _mm256_andnot_ps(vsign, _mm256_loadu_ps(vp.add(p))));
            p += 8;
        }
        let am = _mm256_max_ps(_mm256_max_ps(am0, am1), _mm256_max_ps(am2, am3));
        let mut m1 = _mm_max_ps(_mm256_castps256_ps128(am), _mm256_extractf128_ps::<1>(am));
        m1 = _mm_max_ps(m1, _mm_movehl_ps(m1, m1));
        m1 = _mm_max_ss(m1, _mm_shuffle_ps::<1>(m1, m1));
        let mut absmax = _mm_cvtss_f32(m1);
        while p < k {
            absmax = absmax.max((*vp.add(p)).abs());
            p += 1;
        }
        let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
        let inv = 1.0 / scale;
        let vinv = _mm256_set1_ps(inv);
        let vhi = _mm256_set1_ps(127.0);
        let vlo = _mm256_set1_ps(-127.0);
        let vhalf = _mm256_set1_ps(0.5);
        let vb127 = _mm256_set1_epi8(127);
        let perm = _mm256_setr_epi32(0, 4, 1, 5, 0, 0, 0, 0);
        p = 0;
        if absmax >= 1e-35 {
            let perm8 = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
            while p + 32 <= k {
                let t0 = _mm256_mul_ps(_mm256_loadu_ps(vp.add(p)), vinv);
                let t1 = _mm256_mul_ps(_mm256_loadu_ps(vp.add(p + 8)), vinv);
                let t2 = _mm256_mul_ps(_mm256_loadu_ps(vp.add(p + 16)), vinv);
                let t3 = _mm256_mul_ps(_mm256_loadu_ps(vp.add(p + 24)), vinv);
                let q0 = q8_round(t0, vhalf, vsign);
                let q1 = q8_round(t1, vhalf, vsign);
                let q2 = q8_round(t2, vhalf, vsign);
                let q3 = q8_round(t3, vhalf, vsign);
                let w0 = _mm256_packs_epi32(q0, q1);
                let w1 = _mm256_packs_epi32(q2, q3);
                let b = _mm256_add_epi8(_mm256_packs_epi16(w0, w1), vb127);
                _mm256_storeu_si256(
                    dst.add(p) as *mut __m256i,
                    _mm256_permutevar8x32_epi32(b, perm8),
                );
                p += 32;
            }
            while p + 16 <= k {
                let t0 = _mm256_mul_ps(_mm256_loadu_ps(vp.add(p)), vinv);
                let t1 = _mm256_mul_ps(_mm256_loadu_ps(vp.add(p + 8)), vinv);
                let q0 = q8_round(t0, vhalf, vsign);
                let q1 = q8_round(t1, vhalf, vsign);
                let w = _mm256_packs_epi32(q0, q1);
                let b = _mm256_add_epi8(_mm256_packs_epi16(w, w), vb127);
                let o = _mm256_permutevar8x32_epi32(b, perm);
                _mm_storeu_si128(dst.add(p) as *mut __m128i, _mm256_castsi256_si128(o));
                p += 16;
            }
        }
        while p + 16 <= k {
            let mut t0 = _mm256_mul_ps(_mm256_loadu_ps(vp.add(p)), vinv);
            let mut t1 = _mm256_mul_ps(_mm256_loadu_ps(vp.add(p + 8)), vinv);
            t0 = _mm256_max_ps(_mm256_min_ps(t0, vhi), vlo);
            t1 = _mm256_max_ps(_mm256_min_ps(t1, vhi), vlo);
            let q0 = q8_round(t0, vhalf, vsign);
            let q1 = q8_round(t1, vhalf, vsign);
            let w = _mm256_packs_epi32(q0, q1);
            let b = _mm256_add_epi8(_mm256_packs_epi16(w, w), vb127);
            let o = _mm256_permutevar8x32_epi32(b, perm);
            _mm_storeu_si128(dst.add(p) as *mut __m128i, _mm256_castsi256_si128(o));
            p += 16;
        }
        while p + 8 <= k {
            let mut t = _mm256_mul_ps(_mm256_loadu_ps(vp.add(p)), vinv);
            t = _mm256_max_ps(_mm256_min_ps(t, vhi), vlo);
            let qv = q8_round(t, vhalf, vsign);
            let w = _mm_packs_epi32(_mm256_castsi256_si128(qv), _mm256_extracti128_si256::<1>(qv));
            _mm_storel_epi64(
                dst.add(p) as *mut __m128i,
                _mm_add_epi8(_mm_packs_epi16(w, w), _mm_set1_epi8(127)),
            );
            p += 8;
        }
        while p < k {
            let t = (*vp.add(p) * inv).clamp(-127.0, 127.0);
            *dst.add(p) = (((t + 0.5f32.copysign(t)) as i32) + 127) as u8;
            p += 1;
        }
        scale
    }
}

/// Scalar replica of the fused int8 tile — the single written-out
/// statement of the quantized tile numerics, the dispatch fallback
/// where no SIMD int8 kernel is installed, and (unlike the SIMD tiles)
/// narrow-capable via `nr`. Because i32 accumulation of the unbiased
/// i8×i8 products is exact, the SIMD tiles are bit-identical to this
/// replica (not merely close) regardless of group order or the
/// corr-subtraction trick.
///
/// The kernel accumulates all `MR` rows (pad rows are quantize-front
/// zero-filled and cost nothing to read) but stores only `mr`; each
/// stored element is the overwrite
/// `C[roff[r] + j] = relu?((acc as f32) * (sa[r]*sb) + bias[j])` —
/// combined scale first (one rounding), dequant multiply, plain bias
/// add, never `mul_add`, then the [`relu_store`] select. This single
/// statement is the store every SIMD tile replicates, which together
/// with exact i32 accumulation makes int8 results bit-identical
/// everywhere.
///
/// # Safety
/// `ap` must cover `MR` rows of `astride` bytes with `kg*QK` readable
/// per row; `bp` one packed panel (`kg*NR*QK` bytes); `sa` `mr` scales;
/// `bias` `nr` floats; `cp + roff[r] .. + nr` in bounds per stored row.
pub unsafe fn tile_i8_scalar(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp: *const i8,
    _corr: *const i32,
    sa: *const f32,
    sb: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
    nr: usize,
) {
    // SAFETY: caller contract (`# Safety` above): reads stay inside the
    // `MR×astride` A block, the `kg·NR·QK`-byte B panel, and the
    // `sa`/`bias` arrays; stores stay inside `cp + roff[r] .. + nr` per
    // stored row.
    unsafe {
        let mut acc = [[0i32; NR]; MR];
        for g in 0..kg {
            let b = bp.add(g * NR * QK);
            for (r, row) in acc.iter_mut().enumerate() {
                let a = ap.add(r * astride + g * QK);
                for (j, slot) in row.iter_mut().enumerate() {
                    let mut s = 0i32;
                    for qi in 0..QK {
                        s += (*a.add(qi) as i32 - 127) * (*b.add(j * QK + qi) as i32);
                    }
                    *slot += s;
                }
            }
        }
        for (r, row) in acc.iter().enumerate().take(mr) {
            let sc = *sa.add(r) * sb;
            let out = cp.add(*roff.add(r));
            for (j, &v) in row.iter().enumerate().take(nr) {
                let mut t = v as f32 * sc + *bias.add(j);
                if relu {
                    t = relu_store(t);
                }
                *out.add(j) = t;
            }
        }
    }
}

/// [`TileI8`] entry of [`I8_SCALAR`]: [`tile_i8_scalar`] at the fixed
/// full width `nr = NR`.
///
/// # Safety
/// The [`TileI8`] contract.
unsafe fn tile_i8_scalar_entry(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp: *const i8,
    corr: *const i32,
    sa: *const f32,
    sb: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
) {
    // SAFETY: the TileI8 contract is the tile_i8_scalar contract at
    // nr = NR.
    unsafe { tile_i8_scalar(kg, ap, astride, bp, corr, sa, sb, bias, relu, cp, roff, mr, NR) }
}

#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::__m256i;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::__m256;

/// `trunc(t + copysign(0.5, t))` per f32 lane, as packed i32 — the
/// vector form of the round-half-away-from-zero statement in
/// [`quantize_row_q8_scalar`], shared by every AVX2 quantize and
/// requantize path so the rounding can never drift between them.
///
/// # Safety
///
/// avx2 must be runtime-verified; pure register math otherwise (every
/// caller is itself an avx2 `#[target_feature]` fn).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn q8_round(t: __m256, vhalf: __m256, vsign: __m256) -> __m256i {
    // SAFETY: caller contract: avx2 is present (every caller is itself an avx2
    // `target_feature` fn); the intrinsics touch registers only.
    unsafe {
        use std::arch::x86_64::{_mm256_add_ps, _mm256_and_ps, _mm256_cvttps_epi32, _mm256_or_ps};
        _mm256_cvttps_epi32(_mm256_add_ps(t, _mm256_or_ps(vhalf, _mm256_and_ps(t, vsign))))
    }
}

/// Accumulate one packed B panel against MR biased-u8 A rows with
/// `vpmaddubsw`+`vpmaddwd`: per group, one 32-byte load of the B group
/// (8 columns × QK k-bytes, one column per 32-bit lane) and one
/// 4-byte broadcast per row, unbiased in-register (`psubb 127` —
/// exact: biased bytes are `0..=254`, so `byte − 127 ∈ −127..=127`
/// never wraps). `vpmaddubsw` multiplies u8×i8, so the broadcast is
/// rewritten as `|a| × sign(b, a)` — products keep their
/// signed×signed values (an `a` of 0 zeroes the `b` lane, so that
/// product is 0 either way). Quantization clamps to ±127 (never −128),
/// so i16 pair sums are ≤ 2·127² = 32258 < i16::MAX and `vpmaddubsw`
/// cannot saturate; `vpmaddwd` against 1s widens exactly to the
/// group's i32 sum. Bit-identical to the [`tile_i8_scalar`]
/// accumulator by i32 exactness.
///
/// # Safety
///
/// avx2 must be runtime-verified; `ap` must hold MR rows of
/// `astride >= kg*QK` bytes and `bp` one `kg*NR*QK`-byte packed panel.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn i8_acc_maddubs(kg: usize, ap: *const u8, astride: usize, bp: *const i8) -> [__m256i; MR] {
    // SAFETY: caller contract: avx2 is present; `ap` holds MR rows of
    // `astride ≥ kg·QK` bytes and `bp` one `kg·NR·QK`-byte packed panel,
    // so the group loads and the 4-byte row broadcasts never leave them.
    unsafe {
        use std::arch::x86_64::{
            _mm256_abs_epi8, _mm256_add_epi32, _mm256_loadu_si256, _mm256_madd_epi16,
            _mm256_maddubs_epi16, _mm256_set1_epi16, _mm256_set1_epi32, _mm256_set1_epi8,
            _mm256_setzero_si256, _mm256_sign_epi8, _mm256_sub_epi8,
        };
        let ones = _mm256_set1_epi16(1);
        let v127 = _mm256_set1_epi8(127);
        let mut acc = [_mm256_setzero_si256(); MR];
        for g in 0..kg {
            let b = _mm256_loadu_si256(bp.add(g * NR * QK) as *const __m256i);
            for (r, slot) in acc.iter_mut().enumerate() {
                let w = (ap.add(r * astride + g * QK) as *const i32).read_unaligned();
                let av = _mm256_sub_epi8(_mm256_set1_epi32(w), v127);
                let prod = _mm256_madd_epi16(
                    _mm256_maddubs_epi16(_mm256_abs_epi8(av), _mm256_sign_epi8(b, av)),
                    ones,
                );
                *slot = _mm256_add_epi32(*slot, prod);
            }
        }
        acc
    }
}

/// Two-panel [`i8_acc_maddubs`]: one A broadcast + unbias feeds both B
/// panels; each panel keeps its own accumulators, so the i32 order —
/// and every bit — matches two single-panel runs.
///
/// # Safety
///
/// avx2 must be runtime-verified; `ap` must hold MR rows of
/// `astride >= kg*QK` bytes and `bp0`/`bp1` one packed panel each.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn i8_acc2_maddubs(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp0: *const i8,
    bp1: *const i8,
) -> ([__m256i; MR], [__m256i; MR]) {
    // SAFETY: caller contract: avx2 is present; `ap` holds MR rows of
    // `astride ≥ kg·QK` bytes and `bp0`/`bp1` each one `kg·NR·QK`-byte
    // packed panel — the loads never leave them.
    unsafe {
        use std::arch::x86_64::{
            _mm256_abs_epi8, _mm256_add_epi32, _mm256_loadu_si256, _mm256_madd_epi16,
            _mm256_maddubs_epi16, _mm256_set1_epi16, _mm256_set1_epi32, _mm256_set1_epi8,
            _mm256_setzero_si256, _mm256_sign_epi8, _mm256_sub_epi8,
        };
        let ones = _mm256_set1_epi16(1);
        let v127 = _mm256_set1_epi8(127);
        let mut acc0 = [_mm256_setzero_si256(); MR];
        let mut acc1 = [_mm256_setzero_si256(); MR];
        for g in 0..kg {
            let b0 = _mm256_loadu_si256(bp0.add(g * NR * QK) as *const __m256i);
            let b1 = _mm256_loadu_si256(bp1.add(g * NR * QK) as *const __m256i);
            for r in 0..MR {
                let w = (ap.add(r * astride + g * QK) as *const i32).read_unaligned();
                let av = _mm256_sub_epi8(_mm256_set1_epi32(w), v127);
                let ua = _mm256_abs_epi8(av);
                acc0[r] = _mm256_add_epi32(
                    acc0[r],
                    _mm256_madd_epi16(_mm256_maddubs_epi16(ua, _mm256_sign_epi8(b0, av)), ones),
                );
                acc1[r] = _mm256_add_epi32(
                    acc1[r],
                    _mm256_madd_epi16(_mm256_maddubs_epi16(ua, _mm256_sign_epi8(b1, av)), ones),
                );
            }
        }
        (acc0, acc1)
    }
}

/// AVX-VNNI accumulator: `vpdpbusd` consumes the **biased** A bytes
/// directly as its u8 operand — no unbias, no sign trick — then the
/// panel's precomputed correction row `corr[c] = 127·Σ_p b[c][p]`
/// (`QuantPackedB::corr`) is subtracted once after the `k` loop:
/// `Σ(q+127)·b − 127·Σb = Σq·b`, all in exact i32 (k ≤ a few thousand
/// keeps `Σ` far from overflow), so still bit-identical to
/// [`tile_i8_scalar`]. One fused dot-accumulate per row per group
/// instead of maddubs' four-op chain.
///
/// # Safety
///
/// avx2+avxvnni must be runtime-verified; `ap` must hold MR rows of
/// `astride >= kg*QK` bytes, `bp` one packed panel, and `corr` that
/// panel's NR-lane i32 correction row.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "avxvnni")]
#[inline]
unsafe fn i8_acc_vnni(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp: *const i8,
    corr: *const i32,
) -> [__m256i; MR] {
    // SAFETY: caller contract: avx2+avxvnni are present; `ap` holds MR rows of
    // `astride ≥ kg·QK` bytes, `bp` one `kg·NR·QK`-byte panel, and
    // `corr` that panel's NR-lane i32 correction row.
    unsafe {
        use std::arch::x86_64::{
            _mm256_dpbusd_avx_epi32, _mm256_loadu_si256, _mm256_set1_epi32, _mm256_setzero_si256,
            _mm256_sub_epi32,
        };
        let mut acc = [_mm256_setzero_si256(); MR];
        for g in 0..kg {
            let b = _mm256_loadu_si256(bp.add(g * NR * QK) as *const __m256i);
            for (r, slot) in acc.iter_mut().enumerate() {
                let w = (ap.add(r * astride + g * QK) as *const i32).read_unaligned();
                *slot = _mm256_dpbusd_avx_epi32(*slot, _mm256_set1_epi32(w), b);
            }
        }
        let vc = _mm256_loadu_si256(corr as *const __m256i);
        for slot in acc.iter_mut() {
            *slot = _mm256_sub_epi32(*slot, vc);
        }
        acc
    }
}

/// Two-panel [`i8_acc_vnni`].
///
/// # Safety
///
/// avx2+avxvnni must be runtime-verified; `ap` must hold MR rows of
/// `astride >= kg*QK` bytes, `bp0`/`bp1` one packed panel each, and
/// `corr0`/`corr1` their NR-lane i32 correction rows.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "avxvnni")]
#[inline]
unsafe fn i8_acc2_vnni(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp0: *const i8,
    bp1: *const i8,
    corr0: *const i32,
    corr1: *const i32,
) -> ([__m256i; MR], [__m256i; MR]) {
    // SAFETY: caller contract: avx2+avxvnni are present; `ap` holds MR rows of
    // `astride ≥ kg·QK` bytes, `bp0`/`bp1` one packed panel each, and
    // `corr0`/`corr1` their NR-lane i32 correction rows.
    unsafe {
        use std::arch::x86_64::{
            _mm256_dpbusd_avx_epi32, _mm256_loadu_si256, _mm256_set1_epi32, _mm256_setzero_si256,
            _mm256_sub_epi32,
        };
        let mut acc0 = [_mm256_setzero_si256(); MR];
        let mut acc1 = [_mm256_setzero_si256(); MR];
        for g in 0..kg {
            let b0 = _mm256_loadu_si256(bp0.add(g * NR * QK) as *const __m256i);
            let b1 = _mm256_loadu_si256(bp1.add(g * NR * QK) as *const __m256i);
            for r in 0..MR {
                let w = (ap.add(r * astride + g * QK) as *const i32).read_unaligned();
                let av = _mm256_set1_epi32(w);
                acc0[r] = _mm256_dpbusd_avx_epi32(acc0[r], av, b0);
                acc1[r] = _mm256_dpbusd_avx_epi32(acc1[r], av, b1);
            }
        }
        let vc0 = _mm256_loadu_si256(corr0 as *const __m256i);
        let vc1 = _mm256_loadu_si256(corr1 as *const __m256i);
        for r in 0..MR {
            acc0[r] = _mm256_sub_epi32(acc0[r], vc0);
            acc1[r] = _mm256_sub_epi32(acc1[r], vc1);
        }
        (acc0, acc1)
    }
}

/// Shared dequantizing store of the SIMD tiles: per stored row,
/// `cvtdq2ps` the accumulator, multiply by the broadcast combined scale
/// `sa[r]*sb` (scalar product first — same single rounding as the
/// scalar statement), add the bias vector, `maxps` against zero for
/// ReLU (±0.0 and NaN normalize to `+0.0`, identical to
/// [`relu_store`]), and store 8 floats at `cp + roff[r]`.
///
/// # Safety
///
/// avx2 must be runtime-verified; `bias` must hold NR floats, `sa` `mr`
/// row scales, `roff` MR offsets, and `cp + roff[r] .. + NR` must be in
/// bounds for each of the `mr` rows (the TileI8 contract).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn i8_store_rows(
    acc: [__m256i; MR],
    sa: *const f32,
    sb: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
) {
    // SAFETY: caller contract: avx2 is present; `bias` holds NR floats, `sa`
    // `mr` row scales, `roff` MR offsets, and each 8-float store lands
    // in `cp + roff[r] .. + NR`, in bounds per the TileI8 contract.
    unsafe {
        use std::arch::x86_64::{
            _mm256_add_ps, _mm256_cvtepi32_ps, _mm256_loadu_ps, _mm256_max_ps, _mm256_mul_ps,
            _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps,
        };
        let vb = _mm256_loadu_ps(bias);
        let vz = _mm256_setzero_ps();
        for (r, &a) in acc.iter().enumerate().take(mr) {
            let mut t = _mm256_mul_ps(_mm256_cvtepi32_ps(a), _mm256_set1_ps(*sa.add(r) * sb));
            t = _mm256_add_ps(t, vb);
            if relu {
                t = _mm256_max_ps(t, vz);
            }
            _mm256_storeu_ps(cp.add(*roff.add(r)), t);
        }
    }
}

/// Two-panel [`i8_store_rows`]: 16 floats per row (`roff[r]` and
/// `roff[r] + NR`). The combined scale is formed as
/// `set1(sa[r]) * set1(sb)` — elementwise the same single-rounded
/// product `sa[r]*sb` as the scalar statement.
///
/// # Safety
///
/// avx2 must be runtime-verified; `bias` must hold 2*NR floats, `sa`
/// `mr` row scales, `roff` MR offsets, and `cp + roff[r] .. + 2*NR`
/// must be in bounds for each of the `mr` rows (the TileI8X2 contract).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn i8_store_rows_x2(
    acc0: [__m256i; MR],
    acc1: [__m256i; MR],
    sa: *const f32,
    sb0: f32,
    sb1: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
) {
    // SAFETY: caller contract: avx2 is present; `bias` holds 2·NR floats, `sa`
    // `mr` row scales, `roff` MR offsets, and each pair of 8-float
    // stores lands in `cp + roff[r] .. + 2·NR`, in bounds per the
    // TileI8X2 contract.
    unsafe {
        use std::arch::x86_64::{
            _mm256_add_ps, _mm256_cvtepi32_ps, _mm256_loadu_ps, _mm256_max_ps, _mm256_mul_ps,
            _mm256_set1_ps, _mm256_setzero_ps, _mm256_storeu_ps,
        };
        let vb0 = _mm256_loadu_ps(bias);
        let vb1 = _mm256_loadu_ps(bias.add(NR));
        let vz = _mm256_setzero_ps();
        for r in 0..mr {
            let sc = _mm256_set1_ps(*sa.add(r));
            let mut t0 =
                _mm256_mul_ps(_mm256_cvtepi32_ps(acc0[r]), _mm256_mul_ps(sc, _mm256_set1_ps(sb0)));
            let mut t1 =
                _mm256_mul_ps(_mm256_cvtepi32_ps(acc1[r]), _mm256_mul_ps(sc, _mm256_set1_ps(sb1)));
            t0 = _mm256_add_ps(t0, vb0);
            t1 = _mm256_add_ps(t1, vb1);
            if relu {
                t0 = _mm256_max_ps(t0, vz);
                t1 = _mm256_max_ps(t1, vz);
            }
            let out = cp.add(*roff.add(r));
            _mm256_storeu_ps(out, t0);
            _mm256_storeu_ps(out.add(NR), t1);
        }
    }
}

/// The register-fused leaf epilogue: dequant + bias + ReLU as in
/// [`i8_store_rows_x2`], then **requantize** the 16-float row in
/// registers — absmax via `maxps` of the two (post-ReLU, hence
/// non-negative) halves and the same horizontal max tree as
/// [`quantize_row_q8_avx2`], the clamped quantize statement, then
/// `packs_epi32`/`packs_epi16`/bias `+127`/`permutevar(0,4,1,5,·)`
/// into one 16-byte store. Bit-identical to storing the f32 row and
/// calling the row quantizer on it: the absmax is a pure max tree
/// (order-insensitive), f32 store/load is lossless, and the clamp
/// never fires for normal absmax (the row quantizer's clamp-free
/// fast-path proof) while matching the clamped statement for the
/// degenerate rest.
///
/// # Safety
///
/// avx2 must be runtime-verified; `bias` must hold 2*NR floats,
/// `qdst + r*qstride ..` must admit a 16-byte store per row, and
/// `sa_out` must hold `mr` slots (the TileI8Leaf contract).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[inline]
unsafe fn i8_leaf_requant_rows(
    acc0: [__m256i; MR],
    acc1: [__m256i; MR],
    sa: *const f32,
    sb0: f32,
    sb1: f32,
    bias: *const f32,
    qdst: *mut u8,
    qstride: usize,
    sa_out: *mut f32,
    mr: usize,
) {
    // SAFETY: caller contract: avx2 is present; `bias` holds 2·NR floats, each
    // 16-byte store lands in `qdst + r·qstride ..`, and `sa_out` holds
    // `mr` slots, per the TileI8Leaf contract.
    unsafe {
        use std::arch::x86_64::{
            __m128i, _mm256_add_epi8, _mm256_add_ps, _mm256_castps256_ps128, _mm256_castsi256_si128,
            _mm256_cvtepi32_ps, _mm256_extractf128_ps, _mm256_loadu_ps, _mm256_max_ps,
            _mm256_min_ps, _mm256_mul_ps, _mm256_packs_epi16, _mm256_packs_epi32,
            _mm256_permutevar8x32_epi32, _mm256_set1_epi8, _mm256_set1_ps, _mm256_setr_epi32,
            _mm256_setzero_ps, _mm_cvtss_f32, _mm_max_ps, _mm_max_ss, _mm_movehl_ps, _mm_shuffle_ps,
            _mm_storeu_si128,
        };
        let vb0 = _mm256_loadu_ps(bias);
        let vb1 = _mm256_loadu_ps(bias.add(NR));
        let vz = _mm256_setzero_ps();
        let vsign = _mm256_set1_ps(-0.0);
        let vhi = _mm256_set1_ps(127.0);
        let vlo = _mm256_set1_ps(-127.0);
        let vhalf = _mm256_set1_ps(0.5);
        let vb127 = _mm256_set1_epi8(127);
        let perm = _mm256_setr_epi32(0, 4, 1, 5, 0, 0, 0, 0);
        for r in 0..mr {
            let sc = _mm256_set1_ps(*sa.add(r));
            let t0 =
                _mm256_mul_ps(_mm256_cvtepi32_ps(acc0[r]), _mm256_mul_ps(sc, _mm256_set1_ps(sb0)));
            let t1 =
                _mm256_mul_ps(_mm256_cvtepi32_ps(acc1[r]), _mm256_mul_ps(sc, _mm256_set1_ps(sb1)));
            let t0 = _mm256_max_ps(_mm256_add_ps(t0, vb0), vz);
            let t1 = _mm256_max_ps(_mm256_add_ps(t1, vb1), vz);
            let am = _mm256_max_ps(t0, t1);
            let mut m1 = _mm_max_ps(_mm256_castps256_ps128(am), _mm256_extractf128_ps::<1>(am));
            m1 = _mm_max_ps(m1, _mm_movehl_ps(m1, m1));
            m1 = _mm_max_ss(m1, _mm_shuffle_ps::<1>(m1, m1));
            let absmax = _mm_cvtss_f32(m1);
            let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
            let vinv = _mm256_set1_ps(1.0 / scale);
            let u0 = _mm256_max_ps(_mm256_min_ps(_mm256_mul_ps(t0, vinv), vhi), vlo);
            let u1 = _mm256_max_ps(_mm256_min_ps(_mm256_mul_ps(t1, vinv), vhi), vlo);
            let q0 = q8_round(u0, vhalf, vsign);
            let q1 = q8_round(u1, vhalf, vsign);
            let w = _mm256_packs_epi32(q0, q1);
            let bb = _mm256_add_epi8(_mm256_packs_epi16(w, w), vb127);
            let o = _mm256_permutevar8x32_epi32(bb, perm);
            _mm_storeu_si128(qdst.add(r * qstride) as *mut __m128i, _mm256_castsi256_si128(o));
            *sa_out.add(r) = scale;
        }
    }
}

/// [`TileI8`] entry of [`I8_MADDUBS`].
///
/// # Safety
/// The [`TileI8`] contract; installed only behind runtime avx2
/// detection.
#[cfg(target_arch = "x86_64")]
unsafe fn tile_i8_maddubs_entry(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp: *const i8,
    _corr: *const i32,
    sa: *const f32,
    sb: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
) {
    // SAFETY: caller upholds the TileI8 contract; avx2 is detected
    // before this entry is installed in a kernel set.
    unsafe {
        let acc = i8_acc_maddubs(kg, ap, astride, bp);
        i8_store_rows(acc, sa, sb, bias, relu, cp, roff, mr);
    }
}

/// [`TileI8X2`] entry of [`I8_MADDUBS`].
///
/// # Safety
/// The [`TileI8X2`] contract; installed only behind runtime avx2
/// detection.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile_i8_x2_maddubs_entry(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp0: *const i8,
    bp1: *const i8,
    _corr0: *const i32,
    _corr1: *const i32,
    sa: *const f32,
    sb0: f32,
    sb1: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
) {
    // SAFETY: caller upholds the TileI8X2 contract; avx2 detected.
    unsafe {
        let (acc0, acc1) = i8_acc2_maddubs(kg, ap, astride, bp0, bp1);
        i8_store_rows_x2(acc0, acc1, sa, sb0, sb1, bias, relu, cp, roff, mr);
    }
}

/// [`TileI8Leaf`] entry of [`I8_MADDUBS`].
///
/// # Safety
/// The [`TileI8Leaf`] contract; installed only behind runtime avx2
/// detection.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile_i8_leaf_maddubs_entry(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp0: *const i8,
    bp1: *const i8,
    _corr0: *const i32,
    _corr1: *const i32,
    sa: *const f32,
    sb0: f32,
    sb1: f32,
    bias: *const f32,
    qdst: *mut u8,
    qstride: usize,
    sa_out: *mut f32,
    mr: usize,
) {
    // SAFETY: caller upholds the TileI8Leaf contract; avx2 detected.
    unsafe {
        let (acc0, acc1) = i8_acc2_maddubs(kg, ap, astride, bp0, bp1);
        i8_leaf_requant_rows(acc0, acc1, sa, sb0, sb1, bias, qdst, qstride, sa_out, mr);
    }
}

/// [`TileI8`] entry of [`I8_VNNI`].
///
/// # Safety
/// The [`TileI8`] contract; installed only behind runtime avx2+avxvnni
/// detection.
#[cfg(target_arch = "x86_64")]
unsafe fn tile_i8_vnni_entry(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp: *const i8,
    corr: *const i32,
    sa: *const f32,
    sb: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
) {
    // SAFETY: caller upholds the TileI8 contract; avx2+avxvnni are
    // detected before this entry is installed in a kernel set.
    unsafe {
        let acc = i8_acc_vnni(kg, ap, astride, bp, corr);
        i8_store_rows(acc, sa, sb, bias, relu, cp, roff, mr);
    }
}

/// [`TileI8X2`] entry of [`I8_VNNI`].
///
/// # Safety
/// The [`TileI8X2`] contract; installed only behind runtime
/// avx2+avxvnni detection.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile_i8_x2_vnni_entry(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp0: *const i8,
    bp1: *const i8,
    corr0: *const i32,
    corr1: *const i32,
    sa: *const f32,
    sb0: f32,
    sb1: f32,
    bias: *const f32,
    relu: bool,
    cp: *mut f32,
    roff: *const usize,
    mr: usize,
) {
    // SAFETY: caller upholds the TileI8X2 contract; avx2+avxvnni
    // detected.
    unsafe {
        let (acc0, acc1) = i8_acc2_vnni(kg, ap, astride, bp0, bp1, corr0, corr1);
        i8_store_rows_x2(acc0, acc1, sa, sb0, sb1, bias, relu, cp, roff, mr);
    }
}

/// [`TileI8Leaf`] entry of [`I8_VNNI`].
///
/// # Safety
/// The [`TileI8Leaf`] contract; installed only behind runtime
/// avx2+avxvnni detection.
#[cfg(target_arch = "x86_64")]
#[allow(clippy::too_many_arguments)]
unsafe fn tile_i8_leaf_vnni_entry(
    kg: usize,
    ap: *const u8,
    astride: usize,
    bp0: *const i8,
    bp1: *const i8,
    corr0: *const i32,
    corr1: *const i32,
    sa: *const f32,
    sb0: f32,
    sb1: f32,
    bias: *const f32,
    qdst: *mut u8,
    qstride: usize,
    sa_out: *mut f32,
    mr: usize,
) {
    // SAFETY: caller upholds the TileI8Leaf contract; avx2+avxvnni
    // detected.
    unsafe {
        let (acc0, acc1) = i8_acc2_vnni(kg, ap, astride, bp0, bp1, corr0, corr1);
        i8_leaf_requant_rows(acc0, acc1, sa, sb0, sb1, bias, qdst, qstride, sa_out, mr);
    }
}

/// The AVX2 int8 kernel set (`vpmaddubsw`+`vpmaddwd` accumulate).
#[cfg(target_arch = "x86_64")]
pub static I8_MADDUBS: I8Kernels = I8Kernels {
    label: "avx2-maddubs",
    quant_row: quantize_row_q8_avx2_entry,
    tile: tile_i8_maddubs_entry,
    tile_x2: Some(tile_i8_x2_maddubs_entry),
    tile_leaf: Some(tile_i8_leaf_maddubs_entry),
};

/// The AVX-VNNI int8 kernel set (`vpdpbusd` accumulate over the biased
/// bytes, corr-subtracted).
#[cfg(target_arch = "x86_64")]
pub static I8_VNNI: I8Kernels = I8Kernels {
    label: "avx-vnni",
    quant_row: quantize_row_q8_avx2_entry,
    tile: tile_i8_vnni_entry,
    tile_x2: Some(tile_i8_x2_vnni_entry),
    tile_leaf: Some(tile_i8_leaf_vnni_entry),
};

// ---------------------------------------------------------------------------
// Routing dot product (the tree-descent kernel).
// ---------------------------------------------------------------------------

/// Stripe width of the routing dot: 16 independent accumulator lanes
/// (two 8-wide SIMD chains on AVX, four 4-wide on NEON), reduced by a
/// fixed pairwise tree.
pub const RDOT_LANES: usize = 16;

/// The boundary-logit dot product every tree-descent path uses.
///
/// Fixed numerics: products are accumulated into [`RDOT_LANES`]
/// independent lanes (`lane = p mod 16`) and reduced by a fixed pairwise
/// tree, using separate multiply and add (never FMA). Every ISA path
/// performs the *same* IEEE operations in the *same* order, so
/// [`routing_dot`] is bit-identical across ISAs, batch shapes, and
/// thread counts — which is what lets `route`, `route_batch`, and the
/// training model's `leaf_index` guarantee identical descent decisions
/// (a logit on the wrong side of zero would silently route to a
/// different leaf).
#[inline]
pub fn routing_dot(a: &[f32], b: &[f32]) -> f32 {
    (table().routing_dot)(a, b)
}

/// Fixed reduction tree over the 16 accumulator lanes.
#[inline]
fn rdot_reduce(acc: &[f32; RDOT_LANES]) -> f32 {
    let s0 = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    let s1 = (acc[4] + acc[5]) + (acc[6] + acc[7]);
    let s2 = (acc[8] + acc[9]) + (acc[10] + acc[11]);
    let s3 = (acc[12] + acc[13]) + (acc[14] + acc[15]);
    (s0 + s1) + (s2 + s3)
}

/// Scalar replica of the SIMD routing dots (same lanes, same order) —
/// the portable fallback and the golden-fixture reference.
pub fn routing_dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = [0.0f32; RDOT_LANES];
    let mut p = 0;
    while p + RDOT_LANES <= n {
        for q in 0..RDOT_LANES {
            acc[q] += a[p + q] * b[p + q];
        }
        p += RDOT_LANES;
    }
    while p < n {
        acc[p % RDOT_LANES] += a[p] * b[p];
        p += 1;
    }
    rdot_reduce(&acc)
}

/// Table entry for the AVX routing dot.
#[cfg(target_arch = "x86_64")]
fn routing_dot_avx_entry(a: &[f32], b: &[f32]) -> f32 {
    // Real assert: the kernel reads `b` through raw pointers up to
    // `a.len()`, and this entry is reachable from safe code.
    assert_eq!(a.len(), b.len(), "routing_dot: length mismatch");
    // SAFETY: installed in the table only after runtime avx detection;
    // lengths asserted equal above.
    unsafe { routing_dot_avx(a, b) }
}

/// Two 8-wide mul+add chains; bit-identical to [`routing_dot_scalar`]
/// because each SIMD lane is an independent IEEE add chain and the
/// writeback feeds the same fixed reduction tree.
///
/// # Safety
///
/// avx must be runtime-verified by the caller and `a.len() == b.len()`
/// must hold — the `_entry` wrapper asserts both.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn routing_dot_avx(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: caller contract: avx is present and `a.len() == b.len()` (the
    // entry asserts it); the 16-lane loads stop at `n - RDOT_LANES` and
    // the tail uses safe indexing.
    unsafe {
        use std::arch::x86_64::{
            _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_setzero_ps, _mm256_storeu_ps,
        };
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut p = 0usize;
        while p + RDOT_LANES <= n {
            let prod0 = _mm256_mul_ps(_mm256_loadu_ps(ap.add(p)), _mm256_loadu_ps(bp.add(p)));
            let prod1 =
                _mm256_mul_ps(_mm256_loadu_ps(ap.add(p + 8)), _mm256_loadu_ps(bp.add(p + 8)));
            acc0 = _mm256_add_ps(acc0, prod0);
            acc1 = _mm256_add_ps(acc1, prod1);
            p += RDOT_LANES;
        }
        let mut acc = [0.0f32; RDOT_LANES];
        _mm256_storeu_ps(acc.as_mut_ptr(), acc0);
        _mm256_storeu_ps(acc.as_mut_ptr().add(8), acc1);
        while p < n {
            acc[p % RDOT_LANES] += a[p] * b[p];
            p += 1;
        }
        rdot_reduce(&acc)
    }
}

/// Table entry for the NEON routing dot.
#[cfg(target_arch = "aarch64")]
fn routing_dot_neon_entry(a: &[f32], b: &[f32]) -> f32 {
    // Real assert — see routing_dot_avx_entry.
    assert_eq!(a.len(), b.len(), "routing_dot: length mismatch");
    // SAFETY: installed in the table only after runtime neon detection;
    // lengths asserted equal above.
    unsafe { routing_dot_neon(a, b) }
}

/// Four 4-wide mul+add chains — NEON q-register lanes 0..4/4..8/8..12/
/// 12..16 map exactly onto the scalar replica's 16 stripe lanes, so the
/// aarch64 descent is bit-identical to x86 and to the scalar fallback
/// (this replaces the scalar stripe-16 replica as the aarch64 path).
///
/// # Safety
///
/// neon must be runtime-verified by the caller and `a.len() == b.len()`
/// must hold — the `_entry` wrapper asserts both.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn routing_dot_neon(a: &[f32], b: &[f32]) -> f32 {
    // SAFETY: caller contract: neon is present and `a.len() == b.len()` (the
    // entry asserts it); the 16-lane loads stop at `n - RDOT_LANES` and
    // the tail uses safe indexing.
    unsafe {
        use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};
        debug_assert_eq!(a.len(), b.len());
        let n = a.len();
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut acc2 = vdupq_n_f32(0.0);
        let mut acc3 = vdupq_n_f32(0.0);
        let mut p = 0usize;
        while p + RDOT_LANES <= n {
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(ap.add(p)), vld1q_f32(bp.add(p))));
            acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(ap.add(p + 4)), vld1q_f32(bp.add(p + 4))));
            acc2 = vaddq_f32(acc2, vmulq_f32(vld1q_f32(ap.add(p + 8)), vld1q_f32(bp.add(p + 8))));
            acc3 = vaddq_f32(acc3, vmulq_f32(vld1q_f32(ap.add(p + 12)), vld1q_f32(bp.add(p + 12))));
            p += RDOT_LANES;
        }
        let mut acc = [0.0f32; RDOT_LANES];
        vst1q_f32(acc.as_mut_ptr(), acc0);
        vst1q_f32(acc.as_mut_ptr().add(4), acc1);
        vst1q_f32(acc.as_mut_ptr().add(8), acc2);
        vst1q_f32(acc.as_mut_ptr().add(12), acc3);
        while p < n {
            acc[p % RDOT_LANES] += a[p] * b[p];
            p += 1;
        }
        rdot_reduce(&acc)
    }
}

/// Prefetch a weight row the descent will need a few samples from now.
///
/// The level-synchronous router knows every sample's next node row up
/// front (unlike the dependent per-sample walk, whose next address exists
/// only after the current dot resolves), so it can hide DRAM latency on
/// deep, larger-than-cache levels. No-op where no prefetch intrinsic is
/// wired up.
#[inline]
pub fn prefetch_slice(row: &[f32]) {
    // Gated off under Miri: `_mm_prefetch` is a hint intrinsic the
    // interpreter has no reason to support, and a no-op loses nothing.
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T1};
        let ptr = row.as_ptr();
        let mut p = 0usize;
        // One prefetch per 64-byte line.
        while p < row.len() {
            // SAFETY: `ptr + p` stays inside `row`; prefetch cannot fault.
            unsafe { _mm_prefetch::<_MM_HINT_T1>(ptr.add(p) as *const i8) };
            p += 16;
        }
    }
    #[cfg(not(all(target_arch = "x86_64", not(miri))))]
    {
        let _ = row;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn kind_parse_roundtrip() {
        for kind in KernelKind::ALL {
            assert_eq!(KernelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(KernelKind::parse("fast"), None);
    }

    #[test]
    fn force_overrides_and_clears() {
        let _serialize = force_lock();
        let before = active();
        force(Some(KernelKind::Banded));
        assert_eq!(active(), KernelKind::Banded);
        force(Some(KernelKind::Serial));
        assert_eq!(active(), KernelKind::Serial);
        force(None);
        assert_eq!(active(), before);
    }

    #[test]
    fn table_is_consistent() {
        let t = table();
        assert!(["avx2-fma", "avx", "neon", "portable"].contains(&t.isa));
        // The microkernel entry must match the fused flag's contract on a
        // probe tile: fused ≡ mul_add replica, unfused ≡ portable tile.
        let mut rng = Rng::seed_from_u64(9);
        let kc = 37;
        let mut ap = vec![0.0f32; kc * MR];
        let mut bp = vec![0.0f32; kc * NR];
        rng.fill_normal(&mut ap, 0.0, 1.0);
        rng.fill_normal(&mut bp, 0.0, 1.0);
        let mut got = vec![0.0f32; MR * NR];
        (t.micro_4x8)(kc, &ap, &bp, &mut got, NR, MR, NR);
        let mut want = vec![0.0f32; MR * NR];
        if t.fused_tile {
            micro_4x8_ref(kc, &ap, &bp, &mut want, NR, MR, NR);
        } else {
            micro_4x8_portable(kc, &ap, &bp, &mut want, NR, MR, NR);
        }
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "microkernel drifted from its {} contract",
            if t.fused_tile { "fused" } else { "portable" }
        );
        // The epilogue kernel under every epilogue, same contract story;
        // with None it must match the base kernel bit for bit.
        let mut bias = vec![0.0f32; NR];
        rng.fill_normal(&mut bias, 0.0, 1.0);
        bias[3] = -0.0;
        for epi in
            [Epilogue::None, Epilogue::Bias(&bias), Epilogue::BiasRelu(&bias)]
        {
            let mut got = vec![0.25f32; MR * NR];
            (t.micro_4x8_epi)(kc, &ap, &bp, &mut got, NR, MR, NR, epi);
            let mut want = vec![0.25f32; MR * NR];
            if t.fused_tile {
                micro_4x8_ref_epi(kc, &ap, &bp, &mut want, NR, MR, NR, epi);
            } else {
                micro_4x8_portable_epi(kc, &ap, &bp, &mut want, NR, MR, NR, epi);
            }
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "epilogue kernel drifted from its contract under {epi:?}"
            );
        }
    }

    #[test]
    fn relu_store_normalizes_zeros_and_nan() {
        assert_eq!(relu_store(2.5), 2.5);
        assert_eq!(relu_store(-1.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(relu_store(-0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(relu_store(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(relu_store(f32::NAN).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn epilogue_boundary_hits_exact_zero_as_positive_zero() {
        // Construct tile sums that land exactly on ±0 at the ReLU
        // boundary: with kc = 0 the accumulator is +0.0, so the stored
        // value is relu((C + 0) + bias). C = -bias makes the pre-ReLU
        // sum exactly +0.0 (IEEE x + (-x) = +0.0), and a -0.0 bias over
        // a +0.0 C exercises the signed-zero add — every case must
        // store literal +0.0 bits, on the dispatched kernel too.
        let c0 = [0.5f32, -0.5, 0.0, -0.0, 1.0, -1.0, 0.25, -0.25];
        let bias = [-0.5f32, 0.5, -0.0, 0.0, -1.0, 1.0, -0.25, 0.25];
        let ap: [f32; 0] = [];
        let bp: [f32; 0] = [];
        let kernels: [Micro4x8Epi; 3] =
            [micro_4x8_ref_epi, micro_4x8_portable_epi, table().micro_4x8_epi];
        for kernel in kernels {
            let mut c = c0.to_vec();
            kernel(0, &ap, &bp, &mut c, NR, 1, NR, Epilogue::BiasRelu(&bias));
            for (j, v) in c.iter().enumerate() {
                assert_eq!(
                    v.to_bits(),
                    0.0f32.to_bits(),
                    "lane {j}: ReLU boundary produced {v} (bits {:#010x}), want +0.0",
                    v.to_bits()
                );
            }
        }
    }

    #[test]
    fn routing_dot_is_bit_identical_to_scalar_replica() {
        // The dispatched kernel (SIMD where available) must reproduce the
        // scalar lane-striped replica bit for bit on every length,
        // including ragged tails — routing correctness rides on it.
        let mut rng = Rng::seed_from_u64(77);
        let mut a = vec![0.0f32; 301];
        let mut b = vec![0.0f32; 301];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        for n in 1..=301 {
            let got = routing_dot(&a[..n], &b[..n]);
            let want = routing_dot_scalar(&a[..n], &b[..n]);
            assert_eq!(got.to_bits(), want.to_bits(), "lane drift at n={n}");
        }
    }

    #[test]
    fn routing_dot_matches_reference_numerically() {
        let mut rng = Rng::seed_from_u64(78);
        for &n in &[1usize, 5, 16, 17, 64, 300] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            let reference: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = routing_dot(&a, &b) as f64;
            assert!((got - reference).abs() < 1e-3, "n={n}: {got} vs {reference}");
        }
    }

    #[test]
    fn micro_ref_and_portable_agree_when_products_are_exact() {
        // With few-significand-bit inputs every product is exact, so the
        // fused and unfused tiles must coincide bit for bit — a cheap
        // cross-check that the two replicas implement the same loop.
        let kc = 11;
        let ap: Vec<f32> = (0..kc * MR).map(|i| (i % 7) as f32 - 3.0).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| (i % 5) as f32 * 0.25 - 0.5).collect();
        let mut c1 = vec![0.0f32; MR * 10];
        let mut c2 = vec![0.0f32; MR * 10];
        micro_4x8_ref(kc, &ap, &bp, &mut c1, 10, 3, 7);
        micro_4x8_portable(kc, &ap, &bp, &mut c2, 10, 3, 7);
        assert_eq!(c1, c2);
    }

    #[test]
    fn precision_parse_roundtrip() {
        for p in Precision::ALL {
            assert_eq!(Precision::parse(p.name()), Some(p));
        }
        assert_eq!(Precision::parse("f16"), None);
    }

    /// `corr[c] = 127·Σ_p bp[c][p]` derived directly from packed panel
    /// bytes — the statement `QuantPackedB` precomputes at quantize time.
    fn derive_corr(bp: &[i8], kg: usize) -> [i32; NR] {
        let mut corr = [0i32; NR];
        for g in 0..kg {
            for (c, slot) in corr.iter_mut().enumerate() {
                for qb in 0..QK {
                    *slot += bp[g * NR * QK + c * QK + qb] as i32;
                }
            }
        }
        for slot in corr.iter_mut() {
            *slot *= 127;
        }
        corr
    }

    #[test]
    fn i8_tiles_match_scalar_replica_bitwise() {
        // Integer accumulation is exact and the dequantizing store is
        // one shared statement, so the dispatched tile — and the
        // two-panel tile against two singles — must equal the scalar
        // replica bit for bit. Byte extremes included: biased 0/254
        // (= ∓127, where vpmaddubsw would saturate if quantization ever
        // emitted −128, and where vpdpbusd's corr subtraction is
        // largest), B at ±127, and an all-zero B column (corr = 0).
        let mut rng = Rng::seed_from_u64(11);
        let ks = table().i8k;
        for kg in [1usize, 2, 7, 64] {
            let astride = kg * QK;
            let mut ap = vec![0u8; MR * astride];
            for v in ap.iter_mut() {
                *v = rng.below(255) as u8; // 0..=254 — 255 never occurs
            }
            ap[0] = 0;
            ap[1] = 254;
            let mut bp0 = vec![0i8; kg * NR * QK];
            let mut bp1 = vec![0i8; kg * NR * QK];
            for v in bp0.iter_mut().chain(bp1.iter_mut()) {
                *v = (rng.below(255) as i32 - 127) as i8;
            }
            bp0[0] = 127;
            bp0[1] = -127;
            for g in 0..kg {
                for qb in 0..QK {
                    bp1[g * NR * QK + 3 * QK + qb] = 0;
                }
            }
            let corr0 = derive_corr(&bp0, kg);
            let corr1 = derive_corr(&bp1, kg);
            let sa = [0.5f32, 0.25, 1.5, 2.0];
            let (sb0, sb1) = (0.125f32, 0.75f32);
            let mut bias = [0.0f32; 2 * NR];
            rng.fill_normal(&mut bias, 0.0, 1.0);
            let roff: [usize; MR] = [0, NR, 2 * NR, 3 * NR];
            let roff2: [usize; MR] = [0, 2 * NR, 4 * NR, 6 * NR];
            for relu in [false, true] {
                for mr in [1usize, MR] {
                    let mut want = vec![f32::NAN; MR * NR];
                    let mut got = vec![f32::NAN; MR * NR];
                    // SAFETY: buffers cover MR rows × NR columns, roff
                    // stays in bounds, panels/corr/sa sized above.
                    unsafe {
                        tile_i8_scalar(
                            kg,
                            ap.as_ptr(),
                            astride,
                            bp0.as_ptr(),
                            corr0.as_ptr(),
                            sa.as_ptr(),
                            sb0,
                            bias.as_ptr(),
                            relu,
                            want.as_mut_ptr(),
                            roff.as_ptr(),
                            mr,
                            NR,
                        );
                        (ks.tile)(
                            kg,
                            ap.as_ptr(),
                            astride,
                            bp0.as_ptr(),
                            corr0.as_ptr(),
                            sa.as_ptr(),
                            sb0,
                            bias.as_ptr(),
                            relu,
                            got.as_mut_ptr(),
                            roff.as_ptr(),
                            mr,
                        );
                    }
                    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                        if i < mr * NR {
                            assert_eq!(
                                g.to_bits(),
                                w.to_bits(),
                                "({}) kg={kg} relu={relu} mr={mr} elem {i}",
                                ks.label
                            );
                        } else {
                            assert!(g.is_nan() && w.is_nan(), "row past mr was stored");
                        }
                    }
                    if let Some(tx2) = ks.tile_x2 {
                        // Two singles (second panel offset by NR in C
                        // and bias) are the bitwise reference.
                        let mut want2 = vec![f32::NAN; MR * 2 * NR];
                        let mut got2 = vec![f32::NAN; MR * 2 * NR];
                        // SAFETY: as above; the x2 tile stores 2·NR
                        // floats per row at roff2[r].
                        unsafe {
                            tile_i8_scalar(
                                kg,
                                ap.as_ptr(),
                                astride,
                                bp0.as_ptr(),
                                corr0.as_ptr(),
                                sa.as_ptr(),
                                sb0,
                                bias.as_ptr(),
                                relu,
                                want2.as_mut_ptr(),
                                roff2.as_ptr(),
                                mr,
                                NR,
                            );
                            tile_i8_scalar(
                                kg,
                                ap.as_ptr(),
                                astride,
                                bp1.as_ptr(),
                                corr1.as_ptr(),
                                sa.as_ptr(),
                                sb1,
                                bias.as_ptr().add(NR),
                                relu,
                                want2.as_mut_ptr().add(NR),
                                roff2.as_ptr(),
                                mr,
                                NR,
                            );
                            tx2(
                                kg,
                                ap.as_ptr(),
                                astride,
                                bp0.as_ptr(),
                                bp1.as_ptr(),
                                corr0.as_ptr(),
                                corr1.as_ptr(),
                                sa.as_ptr(),
                                sb0,
                                sb1,
                                bias.as_ptr(),
                                relu,
                                got2.as_mut_ptr(),
                                roff2.as_ptr(),
                                mr,
                            );
                        }
                        for (i, (g, w)) in got2.iter().zip(want2.iter()).enumerate() {
                            if i < mr * 2 * NR {
                                assert_eq!(
                                    g.to_bits(),
                                    w.to_bits(),
                                    "x2 ({}) kg={kg} relu={relu} mr={mr} elem {i}",
                                    ks.label
                                );
                            }
                        }
                    }
                }
            }
            if let (Some(tleaf), Some(tx2)) = (ks.tile_leaf, ks.tile_x2) {
                // The register-fused leaf tile must equal the unfused
                // reference — x2 store with ReLU, then the row
                // quantizer over each stored 16-float row — in bytes
                // AND scale bits (f32 store/load is lossless, absmax
                // is a pure max tree).
                let ell = 2 * NR;
                let mut a1 = vec![f32::NAN; MR * ell];
                // SAFETY: as above.
                unsafe {
                    tx2(
                        kg,
                        ap.as_ptr(),
                        astride,
                        bp0.as_ptr(),
                        bp1.as_ptr(),
                        corr0.as_ptr(),
                        corr1.as_ptr(),
                        sa.as_ptr(),
                        sb0,
                        sb1,
                        bias.as_ptr(),
                        true,
                        a1.as_mut_ptr(),
                        roff2.as_ptr(),
                        MR,
                    );
                }
                let mut wantq = vec![0u8; MR * ell];
                let mut wants = [0f32; MR];
                for r in 0..MR {
                    let row = &a1[r * ell..(r + 1) * ell];
                    wants[r] = (ks.quant_row)(row, &mut wantq[r * ell..(r + 1) * ell]);
                    // The scalar quantizer agrees too.
                    let mut q2 = vec![0u8; ell];
                    let s2 = quantize_row_q8_scalar(&a1[r * ell..(r + 1) * ell], &mut q2);
                    assert_eq!(s2.to_bits(), wants[r].to_bits());
                    assert_eq!(q2, wantq[r * ell..(r + 1) * ell]);
                }
                let mut gotq = vec![0u8; MR * ell];
                let mut gots = [0f32; MR];
                // SAFETY: qdst covers MR rows of ell bytes at stride ell.
                unsafe {
                    tleaf(
                        kg,
                        ap.as_ptr(),
                        astride,
                        bp0.as_ptr(),
                        bp1.as_ptr(),
                        corr0.as_ptr(),
                        corr1.as_ptr(),
                        sa.as_ptr(),
                        sb0,
                        sb1,
                        bias.as_ptr(),
                        gotq.as_mut_ptr(),
                        ell,
                        gots.as_mut_ptr(),
                        MR,
                    );
                }
                assert_eq!(gotq, wantq, "leaf tile bytes drifted ({}) kg={kg}", ks.label);
                for r in 0..MR {
                    assert_eq!(
                        gots[r].to_bits(),
                        wants[r].to_bits(),
                        "leaf tile scale drifted ({}) kg={kg} row {r}",
                        ks.label
                    );
                }
            }
        }
    }

    #[test]
    fn quantize_row_q8_matches_scalar_and_guards_zero() {
        let ks = table().i8k;
        let mut rng = Rng::seed_from_u64(12);
        // The dispatched quantizer must match the scalar statement in
        // bytes and scale bits on every length class its loops carve
        // (32/16/8-wide plus ragged tails).
        for n in [1usize, 4, 7, 8, 15, 16, 31, 32, 33, 64, 70, 256] {
            let mut v = vec![0.0f32; n];
            rng.fill_normal(&mut v, 0.0, 2.0);
            let mut qs = vec![0u8; n];
            let mut qd = vec![0u8; n];
            let ss = quantize_row_q8_scalar(&v, &mut qs);
            let sd = (ks.quant_row)(&v, &mut qd);
            assert_eq!(ss.to_bits(), sd.to_bits(), "scale drift at n={n} ({})", ks.label);
            assert_eq!(qs, qd, "byte drift at n={n} ({})", ks.label);
            // Round-trip error ≤ scale/2 per element (plus float slop).
            for (&x, &b) in v.iter().zip(qs.iter()) {
                let deq = (b as i32 - 127) as f32 * ss;
                assert!((x - deq).abs() <= 0.5001 * ss, "round-trip off for {x}");
            }
        }
        // All-zero row: scale 1.0, every byte the biased zero — the
        // divide-by-zero guard.
        let zeros = [0.0f32, -0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let mut q = vec![0u8; 9];
        let s = quantize_row_q8_scalar(&zeros, &mut q);
        assert_eq!(s, 1.0);
        assert!(q.iter().all(|&b| b == QA_ZERO));
        let sd = (ks.quant_row)(&zeros, &mut q);
        assert_eq!(sd, 1.0);
        assert!(q.iter().all(|&b| b == QA_ZERO));
        // The absmax element lands exactly on the biased extremes 0/254;
        // 255 (signed +128) is never produced.
        let s = quantize_row_q8_scalar(&[-2.0, 1.0, 0.5, 2.0], &mut q);
        assert_eq!(s, 2.0 / 127.0);
        assert_eq!(q[0], 0);
        assert_eq!(q[3], 254);
        // Denormal absmax: inv overflows to inf, the clamp catches the
        // resulting ±inf — SIMD must take its clamped path here too.
        let tiny = f32::from_bits(1);
        let vts = [tiny, -tiny];
        let ss = quantize_row_q8_scalar(&vts, &mut q);
        let mut qd = vec![0u8; 2];
        let sd = (ks.quant_row)(&vts, &mut qd);
        assert_eq!(ss.to_bits(), sd.to_bits());
        assert_eq!(&q[..2], &qd[..]);
        assert_eq!(qd[0], 254);
        assert_eq!(qd[1], 0);
    }

    #[test]
    fn prefetch_slice_is_a_safe_noop() {
        // Prefetch has no observable effect; this just exercises the
        // pointer arithmetic on ragged lengths under Miri-style review.
        let v = vec![1.0f32; 131];
        prefetch_slice(&v);
        prefetch_slice(&v[..1]);
        prefetch_slice(&[]);
    }

    // ------------------------------------------------------------------
    // By-name entry parity: every SIMD entry registered in `detect`'s
    // tables, exercised under its own name against its scalar replica
    // on one probe shape. The `fff analyze` kernel-parity rule keys on
    // these references; the broad shape/epilogue sweeps live in the
    // table-driven tests above and in tests/golden_vectors.rs. Gated on
    // runtime ISA detection (skip, don't fail, on older hardware) and
    // off under Miri, which cannot execute vendor intrinsics.
    // ------------------------------------------------------------------

    /// One probe tile through a micro-kernel entry and its replica, all
    /// three epilogues, compared bit for bit.
    #[cfg(all(any(target_arch = "x86_64", target_arch = "aarch64"), not(miri)))]
    fn check_micro_entry_pair(
        label: &str,
        entry: Micro4x8,
        entry_epi: Micro4x8Epi,
        replica: Micro4x8,
        replica_epi: Micro4x8Epi,
    ) {
        let mut rng = Rng::seed_from_u64(21);
        let kc = 19;
        let mut ap = vec![0.0f32; kc * MR];
        let mut bp = vec![0.0f32; kc * NR];
        rng.fill_normal(&mut ap, 0.0, 1.0);
        rng.fill_normal(&mut bp, 0.0, 1.0);
        let mut bias = vec![0.0f32; NR];
        rng.fill_normal(&mut bias, 0.0, 1.0);
        let mut got = vec![0.5f32; MR * NR];
        let mut want = vec![0.5f32; MR * NR];
        entry(kc, &ap, &bp, &mut got, NR, MR, NR);
        replica(kc, &ap, &bp, &mut want, NR, MR, NR);
        assert_eq!(bits(&got), bits(&want), "{label}: base entry drifted");
        for epi in [Epilogue::None, Epilogue::Bias(&bias), Epilogue::BiasRelu(&bias)] {
            let mut got = vec![-0.25f32; MR * NR];
            let mut want = vec![-0.25f32; MR * NR];
            entry_epi(kc, &ap, &bp, &mut got, NR, MR, NR, epi);
            replica_epi(kc, &ap, &bp, &mut want, NR, MR, NR, epi);
            assert_eq!(bits(&got), bits(&want), "{label}: epi entry drifted");
        }
    }

    #[cfg(all(any(target_arch = "x86_64", target_arch = "aarch64"), not(miri)))]
    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// One probe panel through an int8 tile-entry trio against the
    /// scalar replica (single tile), two singles (x2 tile), and the
    /// x2+row-quantizer composition (leaf tile).
    #[cfg(all(target_arch = "x86_64", not(miri)))]
    fn check_i8_entry_trio(label: &str, tile: TileI8, tx2: TileI8X2, tleaf: TileI8Leaf) {
        let mut rng = Rng::seed_from_u64(23);
        let kg = 7usize;
        let astride = kg * QK;
        let mut ap = vec![0u8; MR * astride];
        for v in ap.iter_mut() {
            *v = rng.below(255) as u8;
        }
        let mut bp0 = vec![0i8; kg * NR * QK];
        let mut bp1 = vec![0i8; kg * NR * QK];
        for v in bp0.iter_mut().chain(bp1.iter_mut()) {
            *v = (rng.below(255) as i32 - 127) as i8;
        }
        let corr0 = derive_corr(&bp0, kg);
        let corr1 = derive_corr(&bp1, kg);
        let sa = [0.5f32, 0.25, 1.5, 2.0];
        let (sb0, sb1) = (0.125f32, 0.75f32);
        let mut bias = [0.0f32; 2 * NR];
        rng.fill_normal(&mut bias, 0.0, 1.0);
        let roff: [usize; MR] = [0, NR, 2 * NR, 3 * NR];
        let roff2: [usize; MR] = [0, 2 * NR, 4 * NR, 6 * NR];
        for relu in [false, true] {
            let mut want = vec![f32::NAN; MR * NR];
            let mut got = vec![f32::NAN; MR * NR];
            // SAFETY: buffers cover MR rows × NR (resp. 2·NR) columns,
            // roff/roff2 stay in bounds, panels/corr/sa sized above; the
            // caller verified the entry's ISA at runtime.
            unsafe {
                tile_i8_scalar(
                    kg,
                    ap.as_ptr(),
                    astride,
                    bp0.as_ptr(),
                    corr0.as_ptr(),
                    sa.as_ptr(),
                    sb0,
                    bias.as_ptr(),
                    relu,
                    want.as_mut_ptr(),
                    roff.as_ptr(),
                    MR,
                    NR,
                );
                tile(
                    kg,
                    ap.as_ptr(),
                    astride,
                    bp0.as_ptr(),
                    corr0.as_ptr(),
                    sa.as_ptr(),
                    sb0,
                    bias.as_ptr(),
                    relu,
                    got.as_mut_ptr(),
                    roff.as_ptr(),
                    MR,
                );
            }
            assert_eq!(bits(&got), bits(&want), "{label}: tile entry drifted relu={relu}");
            let mut want2 = vec![f32::NAN; MR * 2 * NR];
            let mut got2 = vec![f32::NAN; MR * 2 * NR];
            // SAFETY: as above; the x2 tile stores 2·NR floats per row
            // at roff2[r], and the two reference singles cover the same
            // split (second panel offset by NR in C and bias).
            unsafe {
                tile_i8_scalar(
                    kg,
                    ap.as_ptr(),
                    astride,
                    bp0.as_ptr(),
                    corr0.as_ptr(),
                    sa.as_ptr(),
                    sb0,
                    bias.as_ptr(),
                    relu,
                    want2.as_mut_ptr(),
                    roff2.as_ptr(),
                    MR,
                    NR,
                );
                tile_i8_scalar(
                    kg,
                    ap.as_ptr(),
                    astride,
                    bp1.as_ptr(),
                    corr1.as_ptr(),
                    sa.as_ptr(),
                    sb1,
                    bias.as_ptr().add(NR),
                    relu,
                    want2.as_mut_ptr().add(NR),
                    roff2.as_ptr(),
                    MR,
                    NR,
                );
                tx2(
                    kg,
                    ap.as_ptr(),
                    astride,
                    bp0.as_ptr(),
                    bp1.as_ptr(),
                    corr0.as_ptr(),
                    corr1.as_ptr(),
                    sa.as_ptr(),
                    sb0,
                    sb1,
                    bias.as_ptr(),
                    relu,
                    got2.as_mut_ptr(),
                    roff2.as_ptr(),
                    MR,
                );
            }
            assert_eq!(bits(&got2), bits(&want2), "{label}: x2 entry drifted relu={relu}");
        }
        // Leaf: x2 store with ReLU, then the scalar row quantizer.
        let ell = 2 * NR;
        let mut a1 = vec![f32::NAN; MR * ell];
        // SAFETY: same buffer contracts as the x2 call above.
        unsafe {
            tx2(
                kg,
                ap.as_ptr(),
                astride,
                bp0.as_ptr(),
                bp1.as_ptr(),
                corr0.as_ptr(),
                corr1.as_ptr(),
                sa.as_ptr(),
                sb0,
                sb1,
                bias.as_ptr(),
                true,
                a1.as_mut_ptr(),
                roff2.as_ptr(),
                MR,
            );
        }
        let mut wantq = vec![0u8; MR * ell];
        let mut wants = [0f32; MR];
        for r in 0..MR {
            let (row, qrow) = (&a1[r * ell..(r + 1) * ell], &mut wantq[r * ell..(r + 1) * ell]);
            wants[r] = quantize_row_q8_scalar(row, qrow);
        }
        let mut gotq = vec![0u8; MR * ell];
        let mut gots = [0f32; MR];
        // SAFETY: qdst covers MR rows of ell bytes at stride ell and
        // sa_out holds MR slots.
        unsafe {
            tleaf(
                kg,
                ap.as_ptr(),
                astride,
                bp0.as_ptr(),
                bp1.as_ptr(),
                corr0.as_ptr(),
                corr1.as_ptr(),
                sa.as_ptr(),
                sb0,
                sb1,
                bias.as_ptr(),
                gotq.as_mut_ptr(),
                ell,
                gots.as_mut_ptr(),
                MR,
            );
        }
        assert_eq!(gotq, wantq, "{label}: leaf entry bytes drifted");
        assert_eq!(bits(&gots), bits(&wants), "{label}: leaf entry scales drifted");
    }

    #[cfg(all(target_arch = "x86_64", not(miri)))]
    #[test]
    fn x86_entries_match_replicas_by_name() {
        if std::arch::is_x86_feature_detected!("avx") {
            let mut rng = Rng::seed_from_u64(22);
            let mut a = vec![0.0f32; 67];
            let mut b = vec![0.0f32; 67];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            let got = routing_dot_avx_entry(&a, &b);
            let want = routing_dot_scalar(&a, &b);
            assert_eq!(got.to_bits(), want.to_bits(), "routing_dot_avx_entry drifted");
        }
        if std::arch::is_x86_feature_detected!("avx2")
            && std::arch::is_x86_feature_detected!("fma")
        {
            check_micro_entry_pair(
                "avx2fma",
                micro_4x8_avx2fma_entry,
                micro_4x8_epi_avx2fma_entry,
                micro_4x8_ref,
                micro_4x8_ref_epi,
            );
        }
        if std::arch::is_x86_feature_detected!("avx2") {
            let mut rng = Rng::seed_from_u64(24);
            for n in [1usize, 8, 31, 70] {
                let mut v = vec![0.0f32; n];
                rng.fill_normal(&mut v, 0.0, 2.0);
                let mut qs = vec![0u8; n];
                let mut qd = vec![0u8; n];
                let ss = quantize_row_q8_scalar(&v, &mut qs);
                let sd = quantize_row_q8_avx2_entry(&v, &mut qd);
                assert_eq!(ss.to_bits(), sd.to_bits(), "quantize_row_q8_avx2_entry scale n={n}");
                assert_eq!(qs, qd, "quantize_row_q8_avx2_entry bytes n={n}");
            }
            check_i8_entry_trio(
                "maddubs",
                tile_i8_maddubs_entry,
                tile_i8_x2_maddubs_entry,
                tile_i8_leaf_maddubs_entry,
            );
        }
        if std::arch::is_x86_feature_detected!("avxvnni") {
            check_i8_entry_trio(
                "vnni",
                tile_i8_vnni_entry,
                tile_i8_x2_vnni_entry,
                tile_i8_leaf_vnni_entry,
            );
        }
    }

    #[cfg(all(target_arch = "aarch64", not(miri)))]
    #[test]
    fn neon_entries_match_replicas_by_name() {
        if !std::arch::is_aarch64_feature_detected!("neon") {
            return;
        }
        check_micro_entry_pair(
            "neon",
            micro_4x8_neon_entry,
            micro_4x8_epi_neon_entry,
            micro_4x8_ref,
            micro_4x8_ref_epi,
        );
        let mut rng = Rng::seed_from_u64(25);
        let mut a = vec![0.0f32; 67];
        let mut b = vec![0.0f32; 67];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        let got = routing_dot_neon_entry(&a, &b);
        let want = routing_dot_scalar(&a, &b);
        assert_eq!(got.to_bits(), want.to_bits(), "routing_dot_neon_entry drifted");
    }
}
