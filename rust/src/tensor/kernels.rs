//! Runtime ISA detection and kernel dispatch — the one place the crate
//! decides which machine kernels the hot paths run.
//!
//! Two orthogonal decisions live here (they used to be scattered between
//! `gemm.rs` statics and a ~1 ms timing calibration):
//!
//! * **ISA** ([`table`]): detected once per process. On x86_64 with
//!   AVX2+FMA the packed GEMM path runs the explicit 4x8 intrinsic
//!   microkernel ([`micro_4x8_avx2fma`]) and the routing dot runs the
//!   two-chain AVX kernel; on aarch64 the NEON variants run; anywhere
//!   else the portable auto-vectorized tile and the scalar lane-striped
//!   dot are the fallback. The table is a set of function pointers, so
//!   `gemm`, `gemm_tn`/`gemm_nt`, and the tree-descent routing share one
//!   detection story and benches can label rows with [`KernelTable::isa`].
//! * **GEMM kind** ([`active`]): which execution strategy `gemm_acc`
//!   uses above the FLOP threshold — `packed` (panel packing + the
//!   microkernel from the table), `banded` (the iteration-1 `i-k-j`
//!   kernel per row band), or `serial` (the seed kernel, no pool).
//!   `FFF_GEMM_KERNEL=packed|banded|serial` overrides; tests re-enter
//!   dispatch per case via [`force`]. The old timing calibration is
//!   gone: with the microkernel written in intrinsics, packed wins on
//!   both gcc-style and LLVM codegen (EXPERIMENTS.md §Perf iteration 3),
//!   so the only reason to calibrate — auto-vectorizer variance — no
//!   longer exists.
//!
//! Numerics contracts (what the golden-vector fixtures pin):
//!
//! * The 4x8 microkernel accumulates `acc[r][j] = fma(a_r, b_j, acc[r][j])`
//!   with `p` ascending, then adds the tile into `C` with a separate add.
//!   [`micro_4x8_ref`] is the scalar `f32::mul_add` replica of exactly
//!   that order; the AVX2/FMA and NEON kernels are bit-identical to it.
//!   The portable tile uses separate multiply+add (unfused — what
//!   auto-vectorizers reliably emit), so fused and portable results may
//!   differ by final-rounding ulps; *within* one kernel, results are
//!   bit-identical across band splits and thread counts.
//! * The `_epi` microkernel variants fuse a store-phase [`Epilogue`]
//!   (bias add, bias+ReLU) into the tile writeback: each element stores
//!   `epi(C + acc)`, the same per-element operation order as a separate
//!   elementwise pass over a finished GEMM — so fused and unfused
//!   drivers are bit-identical kind by kind, and [`Epilogue::None`]
//!   degenerates to the base kernels exactly. The ReLU is the masked
//!   select [`relu_store`] (`-0.0`/NaN normalize to `+0.0` on every
//!   ISA; NEON deliberately avoids `vmaxq`, which would propagate NaN).
//! * [`routing_dot`] accumulates into 16 independent lanes
//!   (`lane = p mod 16`, separate mul and add, never FMA) reduced by a
//!   fixed pairwise tree. Every ISA performs the same IEEE operations in
//!   the same order, so routing decisions are bit-identical across x86,
//!   aarch64, and the scalar fallback — the invariant tree descent rides
//!   on (a logit on the wrong side of zero would route to a different
//!   leaf on different hardware).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Microkernel tile: MR rows of `A` × NR columns of `B`.
pub const MR: usize = 4;
pub const NR: usize = 8;

/// Store-phase epilogue of the `_epi` microkernels and the band kernels'
/// write-back: each output element is stored as `C = epi(C + acc)`.
///
/// Numerics contract (what the epilogue golden vectors pin): the bias is
/// added *after* the accumulated tile is added into `C` — per element
/// `(C_partial + acc) + bias[j]` — which is exactly the order a separate
/// bias pass over a finished GEMM produces, so a fused store is
/// bit-identical to `gemm` + elementwise pass for every kernel kind and
/// thread count. The ReLU is [`relu_store`].
#[derive(Clone, Copy, Debug)]
pub enum Epilogue<'a> {
    /// Plain accumulate store: `C += acc`.
    None,
    /// `C = (C + acc) + bias[j]`, bias broadcast over rows.
    Bias(&'a [f32]),
    /// `C = relu_store((C + acc) + bias[j])`.
    BiasRelu(&'a [f32]),
}

impl<'a> Epilogue<'a> {
    /// The epilogue restricted to columns `j0..` (for a column panel).
    #[inline]
    pub fn narrow(self, j0: usize) -> Epilogue<'a> {
        match self {
            Epilogue::None => Epilogue::None,
            Epilogue::Bias(b) => Epilogue::Bias(&b[j0..]),
            Epilogue::BiasRelu(b) => Epilogue::BiasRelu(&b[j0..]),
        }
    }

    /// Scalar application to one stored element — the single written-out
    /// statement of the epilogue every ISA's store phase replicates.
    #[inline]
    pub fn apply(self, j: usize, t: f32) -> f32 {
        match self {
            Epilogue::None => t,
            Epilogue::Bias(b) => t + b[j],
            Epilogue::BiasRelu(b) => relu_store(t + b[j]),
        }
    }

    /// Bias slice length available from column 0 (usize::MAX for `None`),
    /// for the entry-point bounds asserts.
    #[inline]
    fn bias_len(&self) -> usize {
        match self {
            Epilogue::None => usize::MAX,
            Epilogue::Bias(b) | Epilogue::BiasRelu(b) => b.len(),
        }
    }
}

/// The store-phase ReLU: strict `t > 0` keeps `t`, everything else stores
/// a literal `+0.0` — the same compare+mask select the SIMD kernels use,
/// so `-0.0` (and NaN) normalize to `+0.0` identically on every ISA.
#[inline]
pub fn relu_store(t: f32) -> f32 {
    if t > 0.0 {
        t
    } else {
        0.0
    }
}

/// GEMM execution strategy above the FLOP threshold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelKind {
    /// Panel packing + the ISA microkernel from [`table`], row bands on
    /// the pool.
    Packed,
    /// The iteration-1 `i-k-j` kernel per row band on the pool.
    Banded,
    /// The seed serial kernel, no pool dispatch at any size.
    Serial,
}

impl KernelKind {
    /// Every kind, in forced-test-matrix order.
    pub const ALL: [KernelKind; 3] = [KernelKind::Packed, KernelKind::Banded, KernelKind::Serial];

    pub fn name(self) -> &'static str {
        match self {
            KernelKind::Packed => "packed",
            KernelKind::Banded => "banded",
            KernelKind::Serial => "serial",
        }
    }

    pub fn parse(s: &str) -> Option<KernelKind> {
        match s {
            "packed" => Some(KernelKind::Packed),
            "banded" => Some(KernelKind::Banded),
            "serial" => Some(KernelKind::Serial),
            _ => None,
        }
    }
}

/// Programmatic override (0 = none, else kind discriminant + 1).
static FORCED: AtomicU8 = AtomicU8::new(0);

/// The GEMM kind the dispatcher uses *now*: [`force`] override first,
/// then `FFF_GEMM_KERNEL` (read once per process), then `packed`.
pub fn active() -> KernelKind {
    match FORCED.load(Ordering::Relaxed) {
        1 => KernelKind::Packed,
        2 => KernelKind::Banded,
        3 => KernelKind::Serial,
        _ => env_default(),
    }
}

/// Force (or clear) the GEMM kind for subsequent dispatches. This is the
/// re-entry point of the forced-kernel test matrix
/// ([`crate::testing::check_kernels`]): unlike the env override it can
/// change per test case within one process. Forcing sections that assert
/// on [`active`] should hold [`force_lock`] — the override is
/// process-global and `cargo test` runs tests on concurrent threads.
pub fn force(kind: Option<KernelKind>) {
    FORCED.store(kind.map(|k| k as u8 + 1).unwrap_or(0), Ordering::Relaxed);
}

/// Serializes forcing sections against each other (see [`force`]).
pub fn force_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

fn env_default() -> KernelKind {
    static ENV: OnceLock<KernelKind> = OnceLock::new();
    *ENV.get_or_init(|| match std::env::var("FFF_GEMM_KERNEL") {
        Ok(v) => KernelKind::parse(&v).unwrap_or_else(|| {
            eprintln!("FFF_GEMM_KERNEL: unknown kernel {v:?} (want packed|banded|serial); using packed");
            KernelKind::Packed
        }),
        Err(_) => KernelKind::Packed,
    })
}

/// `C[mr×nr] += A-panel · B-panel` over packed panels: `ap` is `kc`
/// MR-groups (zero-padded), `bp` is `kc` NR-groups (zero-padded), `cv`
/// starts at the tile's top-left element with row stride `n`.
pub type Micro4x8 =
    fn(kc: usize, ap: &[f32], bp: &[f32], cv: &mut [f32], n: usize, mr: usize, nr: usize);

/// [`Micro4x8`] with a fused store-phase [`Epilogue`]: the tile is stored
/// as `C = epi(C + acc)` instead of `C += acc`, saving the separate
/// bias/ReLU pass over `C` (which at leaf-GEMM shapes — small `k`, wide
/// `n` — costs as much as the accumulation itself).
pub type Micro4x8Epi = for<'a> fn(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue<'a>,
);

/// The boundary-logit dot product (lane-striped, fixed reduction).
pub type RoutingDotFn = fn(&[f32], &[f32]) -> f32;

/// The per-process kernel set, selected by runtime CPU detection.
pub struct KernelTable {
    /// Detected ISA label for bench rows / diagnostics:
    /// `avx2-fma`, `avx`, `neon`, or `portable`.
    pub isa: &'static str,
    /// Whether [`KernelTable::micro_4x8`] uses fused multiply-add (and is
    /// therefore bit-identical to [`micro_4x8_ref`] rather than to the
    /// portable tile).
    pub fused_tile: bool,
    /// The packed-path GEMM microkernel.
    pub micro_4x8: Micro4x8,
    /// The epilogue-fusing variant of the microkernel; with
    /// [`Epilogue::None`] it is bit-identical to [`KernelTable::micro_4x8`]
    /// (the base kernels are thin `None` wrappers around it).
    pub micro_4x8_epi: Micro4x8Epi,
    /// The tree-descent dot kernel (always ≡ [`routing_dot_scalar`]).
    pub routing_dot: RoutingDotFn,
}

/// The detected kernel table (runs CPU feature detection on first call).
pub fn table() -> &'static KernelTable {
    static TABLE: OnceLock<KernelTable> = OnceLock::new();
    TABLE.get_or_init(detect)
}

fn detect() -> KernelTable {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            return KernelTable {
                isa: "avx2-fma",
                fused_tile: true,
                micro_4x8: micro_4x8_avx2fma_entry,
                micro_4x8_epi: micro_4x8_epi_avx2fma_entry,
                routing_dot: routing_dot_avx_entry,
            };
        }
        if std::arch::is_x86_feature_detected!("avx") {
            // AVX without FMA: the routing dot still gets its two 8-wide
            // chains; the GEMM tile stays on the portable (unfused) form.
            return KernelTable {
                isa: "avx",
                fused_tile: false,
                micro_4x8: micro_4x8_portable,
                micro_4x8_epi: micro_4x8_portable_epi,
                routing_dot: routing_dot_avx_entry,
            };
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        if std::arch::is_aarch64_feature_detected!("neon") {
            return KernelTable {
                isa: "neon",
                fused_tile: true,
                micro_4x8: micro_4x8_neon_entry,
                micro_4x8_epi: micro_4x8_epi_neon_entry,
                routing_dot: routing_dot_neon_entry,
            };
        }
    }
    KernelTable {
        isa: "portable",
        fused_tile: false,
        micro_4x8: micro_4x8_portable,
        micro_4x8_epi: micro_4x8_portable_epi,
        routing_dot: routing_dot_scalar,
    }
}

// ---------------------------------------------------------------------------
// 4x8 GEMM microkernels.
// ---------------------------------------------------------------------------

/// Scalar `f32::mul_add` replica of the fused microkernel contract —
/// the documented accumulation order the AVX2/FMA and NEON kernels are
/// bit-identical to. Slow; exists for golden-vector fixtures and as the
/// single written-out statement of the tile numerics.
pub fn micro_4x8_ref(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
) {
    micro_4x8_ref_epi(kc, ap, bp, cv, n, mr, nr, Epilogue::None)
}

/// [`micro_4x8_ref`] with the fused store-phase epilogue — the scalar
/// `mul_add` contract the AVX2/FMA and NEON `_epi` kernels are
/// bit-identical to. With [`Epilogue::None`] the store degenerates to
/// `C += acc`, so this is also the implementation behind
/// [`micro_4x8_ref`].
pub fn micro_4x8_ref_epi(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    let mut acc = [[0.0f32; NR]; MR];
    for p in 0..kc {
        let a: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        let b: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        for (r, row) in acc.iter_mut().enumerate() {
            for (j, slot) in row.iter_mut().enumerate() {
                *slot = a[r].mul_add(b[j], *slot);
            }
        }
    }
    for r in 0..mr {
        for j in 0..nr {
            cv[r * n + j] = epi.apply(j, cv[r * n + j] + acc[r][j]);
        }
    }
}

/// The portable 4x8 tile: separate multiply+add in a shape LLVM's
/// auto-vectorizer reliably widens (the `matrixmultiply` idiom). The
/// fallback where no intrinsic kernel is installed.
///
/// Accumulators are four `[f32; NR]` arrays whose addresses are never
/// taken, so the compiler can keep the tile in SIMD registers (the
/// prototype showed that forming pointers into them forces a stack
/// spill — EXPERIMENTS.md §Perf, microkernel lesson #1).
pub fn micro_4x8_portable(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
) {
    let mut acc0 = [0.0f32; NR];
    let mut acc1 = [0.0f32; NR];
    let mut acc2 = [0.0f32; NR];
    let mut acc3 = [0.0f32; NR];
    for p in 0..kc {
        let b: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        let a: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        for (acc, &bc) in acc0.iter_mut().zip(b.iter()) {
            *acc += a[0] * bc;
        }
        for (acc, &bc) in acc1.iter_mut().zip(b.iter()) {
            *acc += a[1] * bc;
        }
        for (acc, &bc) in acc2.iter_mut().zip(b.iter()) {
            *acc += a[2] * bc;
        }
        for (acc, &bc) in acc3.iter_mut().zip(b.iter()) {
            *acc += a[3] * bc;
        }
    }
    if mr > 0 {
        for (cj, &s) in cv[..nr].iter_mut().zip(acc0.iter()) {
            *cj += s;
        }
    }
    if mr > 1 {
        for (cj, &s) in cv[n..n + nr].iter_mut().zip(acc1.iter()) {
            *cj += s;
        }
    }
    if mr > 2 {
        for (cj, &s) in cv[2 * n..2 * n + nr].iter_mut().zip(acc2.iter()) {
            *cj += s;
        }
    }
    if mr > 3 {
        for (cj, &s) in cv[3 * n..3 * n + nr].iter_mut().zip(acc3.iter()) {
            *cj += s;
        }
    }
}

/// [`micro_4x8_portable`] with the fused store-phase epilogue: the same
/// unfused mul+add accumulation loop, then `C = epi(C + acc)` in one
/// pass while the tile is still in registers. [`Epilogue::None`] routes
/// to the base tile (identical stores either way).
pub fn micro_4x8_portable_epi(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    if matches!(epi, Epilogue::None) {
        return micro_4x8_portable(kc, ap, bp, cv, n, mr, nr);
    }
    let mut acc0 = [0.0f32; NR];
    let mut acc1 = [0.0f32; NR];
    let mut acc2 = [0.0f32; NR];
    let mut acc3 = [0.0f32; NR];
    for p in 0..kc {
        let b: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        let a: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        for (acc, &bc) in acc0.iter_mut().zip(b.iter()) {
            *acc += a[0] * bc;
        }
        for (acc, &bc) in acc1.iter_mut().zip(b.iter()) {
            *acc += a[1] * bc;
        }
        for (acc, &bc) in acc2.iter_mut().zip(b.iter()) {
            *acc += a[2] * bc;
        }
        for (acc, &bc) in acc3.iter_mut().zip(b.iter()) {
            *acc += a[3] * bc;
        }
    }
    // Spilling the accumulators into one array here is fine: the hot
    // kc loop above never took their addresses.
    let accs = [acc0, acc1, acc2, acc3];
    for (r, acc) in accs.iter().enumerate().take(mr) {
        for (j, &s) in acc.iter().enumerate().take(nr) {
            cv[r * n + j] = epi.apply(j, cv[r * n + j] + s);
        }
    }
}

/// Table entry for the AVX2/FMA kernel.
#[cfg(target_arch = "x86_64")]
fn micro_4x8_avx2fma_entry(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
) {
    micro_4x8_epi_avx2fma_entry(kc, ap, bp, cv, n, mr, nr, Epilogue::None)
}

/// Table entry for the AVX2/FMA kernel with fused epilogue.
#[cfg(target_arch = "x86_64")]
fn micro_4x8_epi_avx2fma_entry(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    // Real asserts, not debug: the table field is `pub`, so safe code can
    // reach this with short panels, and the kernel reads through raw
    // pointers. One branch per tile is noise next to a kc-deep FMA loop.
    assert!(ap.len() >= kc * MR && bp.len() >= kc * NR, "micro_4x8: short panel");
    assert!(mr == 0 || cv.len() >= (mr - 1) * n + nr, "micro_4x8: short C tile");
    // Full-width epilogue tiles load 8 bias lanes with one vector read.
    assert!(epi.bias_len() >= nr, "micro_4x8: short bias");
    // SAFETY: installed in the table only after runtime avx2+fma
    // detection; panel/tile/bias bounds asserted above.
    unsafe { micro_4x8_avx2fma(kc, ap, bp, cv, n, mr, nr, epi) }
}

/// Explicit 4x8 AVX2/FMA microkernel: per `p`, one 8-wide load of the
/// `B` group and four broadcast+FMA updates; the tile lives in four ymm
/// registers for the whole `kc` loop. Bit-identical to
/// [`micro_4x8_ref`]. Measured 62.8/65.6 GF/s serial at 256³/512³ under
/// the compiler whose auto-vectorized tile ran at 11.7 GF/s
/// (EXPERIMENTS.md §Perf iteration 3).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn micro_4x8_avx2fma(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_and_ps, _mm256_broadcast_ss, _mm256_cmp_ps, _mm256_fmadd_ps,
        _mm256_loadu_ps, _mm256_setzero_ps, _mm256_storeu_ps, _CMP_GT_OQ,
    };
    let apt = ap.as_ptr();
    let bpt = bp.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut acc2 = _mm256_setzero_ps();
    let mut acc3 = _mm256_setzero_ps();
    for p in 0..kc {
        let b = _mm256_loadu_ps(bpt.add(p * NR));
        let a = apt.add(p * MR);
        acc0 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a), b, acc0);
        acc1 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a.add(1)), b, acc1);
        acc2 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a.add(2)), b, acc2);
        acc3 = _mm256_fmadd_ps(_mm256_broadcast_ss(&*a.add(3)), b, acc3);
    }
    if nr == NR {
        // Full-width tile: vector read-modify-write per C row, with the
        // epilogue fused into the same store. The ReLU select is
        // `and(t, t > 0)` — bit-identical to [`relu_store`] (NaN and
        // -0.0 both mask to +0.0).
        let c = cv.as_mut_ptr();
        let zero = _mm256_setzero_ps();
        let (bias, relu, fused) = match epi {
            Epilogue::None => (zero, false, false),
            Epilogue::Bias(b) => (_mm256_loadu_ps(b.as_ptr()), false, true),
            Epilogue::BiasRelu(b) => (_mm256_loadu_ps(b.as_ptr()), true, true),
        };
        macro_rules! store_row {
            ($off:expr, $acc:expr) => {{
                let cr = c.add($off);
                let mut t = _mm256_add_ps(_mm256_loadu_ps(cr), $acc);
                if fused {
                    t = _mm256_add_ps(t, bias);
                }
                if relu {
                    t = _mm256_and_ps(t, _mm256_cmp_ps::<_CMP_GT_OQ>(t, zero));
                }
                _mm256_storeu_ps(cr, t);
            }};
        }
        if mr > 0 {
            store_row!(0, acc0);
        }
        if mr > 1 {
            store_row!(n, acc1);
        }
        if mr > 2 {
            store_row!(2 * n, acc2);
        }
        if mr > 3 {
            store_row!(3 * n, acc3);
        }
    } else {
        // Edge tile: spill the accumulators once, then masked scalar
        // writeback through the epilogue (the loop above never took
        // their address).
        let mut t = [[0.0f32; NR]; MR];
        _mm256_storeu_ps(t[0].as_mut_ptr(), acc0);
        _mm256_storeu_ps(t[1].as_mut_ptr(), acc1);
        _mm256_storeu_ps(t[2].as_mut_ptr(), acc2);
        _mm256_storeu_ps(t[3].as_mut_ptr(), acc3);
        for (r, row) in t.iter().enumerate().take(mr) {
            for (j, &s) in row.iter().enumerate().take(nr) {
                cv[r * n + j] = epi.apply(j, cv[r * n + j] + s);
            }
        }
    }
}

/// Table entry for the NEON kernel.
#[cfg(target_arch = "aarch64")]
fn micro_4x8_neon_entry(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
) {
    micro_4x8_epi_neon_entry(kc, ap, bp, cv, n, mr, nr, Epilogue::None)
}

/// Table entry for the NEON kernel with fused epilogue.
#[cfg(target_arch = "aarch64")]
fn micro_4x8_epi_neon_entry(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    // Real asserts, not debug — see micro_4x8_epi_avx2fma_entry.
    assert!(ap.len() >= kc * MR && bp.len() >= kc * NR, "micro_4x8: short panel");
    assert!(mr == 0 || cv.len() >= (mr - 1) * n + nr, "micro_4x8: short C tile");
    assert!(epi.bias_len() >= nr, "micro_4x8: short bias");
    // SAFETY: installed in the table only after runtime neon detection;
    // panel/tile/bias bounds asserted above.
    unsafe { micro_4x8_neon(kc, ap, bp, cv, n, mr, nr, epi) }
}

/// NEON 4x4 microkernel, applied to each 4-column half of the packed
/// 8-wide `B` panel: per `p`, two 4-wide loads of the `B` group and four
/// `vfmaq` updates per half (eight q-register accumulators total). Lane
/// `j` accumulates `fma(a_r, b_j, acc)` with `p` ascending — the same
/// per-lane order as the AVX2 kernel — so NEON output is bit-identical
/// to [`micro_4x8_ref`] too.
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn micro_4x8_neon(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    cv: &mut [f32],
    n: usize,
    mr: usize,
    nr: usize,
    epi: Epilogue,
) {
    use std::arch::aarch64::{
        vaddq_f32, vandq_u32, vcgtq_f32, vdupq_n_f32, vfmaq_f32, vld1q_f32,
        vreinterpretq_f32_u32, vreinterpretq_u32_f32, vst1q_f32,
    };
    let apt = ap.as_ptr();
    let bpt = bp.as_ptr();
    // acc{r}l = lanes 0..4 of row r, acc{r}h = lanes 4..8.
    let mut acc0l = vdupq_n_f32(0.0);
    let mut acc0h = vdupq_n_f32(0.0);
    let mut acc1l = vdupq_n_f32(0.0);
    let mut acc1h = vdupq_n_f32(0.0);
    let mut acc2l = vdupq_n_f32(0.0);
    let mut acc2h = vdupq_n_f32(0.0);
    let mut acc3l = vdupq_n_f32(0.0);
    let mut acc3h = vdupq_n_f32(0.0);
    for p in 0..kc {
        let bl = vld1q_f32(bpt.add(p * NR));
        let bh = vld1q_f32(bpt.add(p * NR + 4));
        let a = apt.add(p * MR);
        let a0 = vdupq_n_f32(*a);
        let a1 = vdupq_n_f32(*a.add(1));
        let a2 = vdupq_n_f32(*a.add(2));
        let a3 = vdupq_n_f32(*a.add(3));
        acc0l = vfmaq_f32(acc0l, a0, bl);
        acc0h = vfmaq_f32(acc0h, a0, bh);
        acc1l = vfmaq_f32(acc1l, a1, bl);
        acc1h = vfmaq_f32(acc1h, a1, bh);
        acc2l = vfmaq_f32(acc2l, a2, bl);
        acc2h = vfmaq_f32(acc2h, a2, bh);
        acc3l = vfmaq_f32(acc3l, a3, bl);
        acc3h = vfmaq_f32(acc3h, a3, bh);
    }
    if nr == NR {
        let c = cv.as_mut_ptr();
        let zero = vdupq_n_f32(0.0);
        let (biasl, biash, relu, fused) = match epi {
            Epilogue::None => (zero, zero, false, false),
            Epilogue::Bias(b) => (vld1q_f32(b.as_ptr()), vld1q_f32(b.as_ptr().add(4)), false, true),
            Epilogue::BiasRelu(b) => {
                (vld1q_f32(b.as_ptr()), vld1q_f32(b.as_ptr().add(4)), true, true)
            }
        };
        // The ReLU select is `and(t, t > 0)` (vcgtq mask), bit-identical
        // to [`relu_store`] — NEON's vmaxq would propagate NaN where x86
        // maxps and the scalar replica return +0.0, so the masked form is
        // the one that matches across ISAs.
        macro_rules! store_row {
            ($off:expr, $accl:expr, $acch:expr) => {{
                let cr = c.add($off);
                let mut tl = vaddq_f32(vld1q_f32(cr), $accl);
                let mut th = vaddq_f32(vld1q_f32(cr.add(4)), $acch);
                if fused {
                    tl = vaddq_f32(tl, biasl);
                    th = vaddq_f32(th, biash);
                }
                if relu {
                    tl = vreinterpretq_f32_u32(vandq_u32(
                        vreinterpretq_u32_f32(tl),
                        vcgtq_f32(tl, zero),
                    ));
                    th = vreinterpretq_f32_u32(vandq_u32(
                        vreinterpretq_u32_f32(th),
                        vcgtq_f32(th, zero),
                    ));
                }
                vst1q_f32(cr, tl);
                vst1q_f32(cr.add(4), th);
            }};
        }
        if mr > 0 {
            store_row!(0, acc0l, acc0h);
        }
        if mr > 1 {
            store_row!(n, acc1l, acc1h);
        }
        if mr > 2 {
            store_row!(2 * n, acc2l, acc2h);
        }
        if mr > 3 {
            store_row!(3 * n, acc3l, acc3h);
        }
    } else {
        let mut t = [[0.0f32; NR]; MR];
        vst1q_f32(t[0].as_mut_ptr(), acc0l);
        vst1q_f32(t[0].as_mut_ptr().add(4), acc0h);
        vst1q_f32(t[1].as_mut_ptr(), acc1l);
        vst1q_f32(t[1].as_mut_ptr().add(4), acc1h);
        vst1q_f32(t[2].as_mut_ptr(), acc2l);
        vst1q_f32(t[2].as_mut_ptr().add(4), acc2h);
        vst1q_f32(t[3].as_mut_ptr(), acc3l);
        vst1q_f32(t[3].as_mut_ptr().add(4), acc3h);
        for (r, row) in t.iter().enumerate().take(mr) {
            for (j, &s) in row.iter().enumerate().take(nr) {
                cv[r * n + j] = epi.apply(j, cv[r * n + j] + s);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Routing dot product (the tree-descent kernel).
// ---------------------------------------------------------------------------

/// Stripe width of the routing dot: 16 independent accumulator lanes
/// (two 8-wide SIMD chains on AVX, four 4-wide on NEON), reduced by a
/// fixed pairwise tree.
pub const RDOT_LANES: usize = 16;

/// The boundary-logit dot product every tree-descent path uses.
///
/// Fixed numerics: products are accumulated into [`RDOT_LANES`]
/// independent lanes (`lane = p mod 16`) and reduced by a fixed pairwise
/// tree, using separate multiply and add (never FMA). Every ISA path
/// performs the *same* IEEE operations in the *same* order, so
/// [`routing_dot`] is bit-identical across ISAs, batch shapes, and
/// thread counts — which is what lets `route`, `route_batch`, and the
/// training model's `leaf_index` guarantee identical descent decisions
/// (a logit on the wrong side of zero would silently route to a
/// different leaf).
#[inline]
pub fn routing_dot(a: &[f32], b: &[f32]) -> f32 {
    (table().routing_dot)(a, b)
}

/// Fixed reduction tree over the 16 accumulator lanes.
#[inline]
fn rdot_reduce(acc: &[f32; RDOT_LANES]) -> f32 {
    let s0 = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    let s1 = (acc[4] + acc[5]) + (acc[6] + acc[7]);
    let s2 = (acc[8] + acc[9]) + (acc[10] + acc[11]);
    let s3 = (acc[12] + acc[13]) + (acc[14] + acc[15]);
    (s0 + s1) + (s2 + s3)
}

/// Scalar replica of the SIMD routing dots (same lanes, same order) —
/// the portable fallback and the golden-fixture reference.
pub fn routing_dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = [0.0f32; RDOT_LANES];
    let mut p = 0;
    while p + RDOT_LANES <= n {
        for q in 0..RDOT_LANES {
            acc[q] += a[p + q] * b[p + q];
        }
        p += RDOT_LANES;
    }
    while p < n {
        acc[p % RDOT_LANES] += a[p] * b[p];
        p += 1;
    }
    rdot_reduce(&acc)
}

/// Table entry for the AVX routing dot.
#[cfg(target_arch = "x86_64")]
fn routing_dot_avx_entry(a: &[f32], b: &[f32]) -> f32 {
    // Real assert: the kernel reads `b` through raw pointers up to
    // `a.len()`, and this entry is reachable from safe code.
    assert_eq!(a.len(), b.len(), "routing_dot: length mismatch");
    // SAFETY: installed in the table only after runtime avx detection;
    // lengths asserted equal above.
    unsafe { routing_dot_avx(a, b) }
}

/// Two 8-wide mul+add chains; bit-identical to [`routing_dot_scalar`]
/// because each SIMD lane is an independent IEEE add chain and the
/// writeback feeds the same fixed reduction tree.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn routing_dot_avx(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_setzero_ps, _mm256_storeu_ps,
    };
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut p = 0usize;
    while p + RDOT_LANES <= n {
        let prod0 = _mm256_mul_ps(_mm256_loadu_ps(ap.add(p)), _mm256_loadu_ps(bp.add(p)));
        let prod1 = _mm256_mul_ps(_mm256_loadu_ps(ap.add(p + 8)), _mm256_loadu_ps(bp.add(p + 8)));
        acc0 = _mm256_add_ps(acc0, prod0);
        acc1 = _mm256_add_ps(acc1, prod1);
        p += RDOT_LANES;
    }
    let mut acc = [0.0f32; RDOT_LANES];
    _mm256_storeu_ps(acc.as_mut_ptr(), acc0);
    _mm256_storeu_ps(acc.as_mut_ptr().add(8), acc1);
    while p < n {
        acc[p % RDOT_LANES] += a[p] * b[p];
        p += 1;
    }
    rdot_reduce(&acc)
}

/// Table entry for the NEON routing dot.
#[cfg(target_arch = "aarch64")]
fn routing_dot_neon_entry(a: &[f32], b: &[f32]) -> f32 {
    // Real assert — see routing_dot_avx_entry.
    assert_eq!(a.len(), b.len(), "routing_dot: length mismatch");
    // SAFETY: installed in the table only after runtime neon detection;
    // lengths asserted equal above.
    unsafe { routing_dot_neon(a, b) }
}

/// Four 4-wide mul+add chains — NEON q-register lanes 0..4/4..8/8..12/
/// 12..16 map exactly onto the scalar replica's 16 stripe lanes, so the
/// aarch64 descent is bit-identical to x86 and to the scalar fallback
/// (this replaces the scalar stripe-16 replica as the aarch64 path).
#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn routing_dot_neon(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::aarch64::{vaddq_f32, vdupq_n_f32, vld1q_f32, vmulq_f32, vst1q_f32};
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = vdupq_n_f32(0.0);
    let mut acc1 = vdupq_n_f32(0.0);
    let mut acc2 = vdupq_n_f32(0.0);
    let mut acc3 = vdupq_n_f32(0.0);
    let mut p = 0usize;
    while p + RDOT_LANES <= n {
        acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(ap.add(p)), vld1q_f32(bp.add(p))));
        acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(ap.add(p + 4)), vld1q_f32(bp.add(p + 4))));
        acc2 = vaddq_f32(acc2, vmulq_f32(vld1q_f32(ap.add(p + 8)), vld1q_f32(bp.add(p + 8))));
        acc3 = vaddq_f32(acc3, vmulq_f32(vld1q_f32(ap.add(p + 12)), vld1q_f32(bp.add(p + 12))));
        p += RDOT_LANES;
    }
    let mut acc = [0.0f32; RDOT_LANES];
    vst1q_f32(acc.as_mut_ptr(), acc0);
    vst1q_f32(acc.as_mut_ptr().add(4), acc1);
    vst1q_f32(acc.as_mut_ptr().add(8), acc2);
    vst1q_f32(acc.as_mut_ptr().add(12), acc3);
    while p < n {
        acc[p % RDOT_LANES] += a[p] * b[p];
        p += 1;
    }
    rdot_reduce(&acc)
}

/// Prefetch a weight row the descent will need a few samples from now.
///
/// The level-synchronous router knows every sample's next node row up
/// front (unlike the dependent per-sample walk, whose next address exists
/// only after the current dot resolves), so it can hide DRAM latency on
/// deep, larger-than-cache levels. No-op where no prefetch intrinsic is
/// wired up.
#[inline]
pub fn prefetch_slice(row: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T1};
        let ptr = row.as_ptr();
        let mut p = 0usize;
        // One prefetch per 64-byte line.
        while p < row.len() {
            // SAFETY: `ptr + p` stays inside `row`; prefetch cannot fault.
            unsafe { _mm_prefetch::<_MM_HINT_T1>(ptr.add(p) as *const i8) };
            p += 16;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = row;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn kind_parse_roundtrip() {
        for kind in KernelKind::ALL {
            assert_eq!(KernelKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(KernelKind::parse("fast"), None);
    }

    #[test]
    fn force_overrides_and_clears() {
        let _serialize = force_lock();
        let before = active();
        force(Some(KernelKind::Banded));
        assert_eq!(active(), KernelKind::Banded);
        force(Some(KernelKind::Serial));
        assert_eq!(active(), KernelKind::Serial);
        force(None);
        assert_eq!(active(), before);
    }

    #[test]
    fn table_is_consistent() {
        let t = table();
        assert!(["avx2-fma", "avx", "neon", "portable"].contains(&t.isa));
        // The microkernel entry must match the fused flag's contract on a
        // probe tile: fused ≡ mul_add replica, unfused ≡ portable tile.
        let mut rng = Rng::seed_from_u64(9);
        let kc = 37;
        let mut ap = vec![0.0f32; kc * MR];
        let mut bp = vec![0.0f32; kc * NR];
        rng.fill_normal(&mut ap, 0.0, 1.0);
        rng.fill_normal(&mut bp, 0.0, 1.0);
        let mut got = vec![0.0f32; MR * NR];
        (t.micro_4x8)(kc, &ap, &bp, &mut got, NR, MR, NR);
        let mut want = vec![0.0f32; MR * NR];
        if t.fused_tile {
            micro_4x8_ref(kc, &ap, &bp, &mut want, NR, MR, NR);
        } else {
            micro_4x8_portable(kc, &ap, &bp, &mut want, NR, MR, NR);
        }
        assert_eq!(
            got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "microkernel drifted from its {} contract",
            if t.fused_tile { "fused" } else { "portable" }
        );
        // The epilogue kernel under every epilogue, same contract story;
        // with None it must match the base kernel bit for bit.
        let mut bias = vec![0.0f32; NR];
        rng.fill_normal(&mut bias, 0.0, 1.0);
        bias[3] = -0.0;
        for epi in
            [Epilogue::None, Epilogue::Bias(&bias), Epilogue::BiasRelu(&bias)]
        {
            let mut got = vec![0.25f32; MR * NR];
            (t.micro_4x8_epi)(kc, &ap, &bp, &mut got, NR, MR, NR, epi);
            let mut want = vec![0.25f32; MR * NR];
            if t.fused_tile {
                micro_4x8_ref_epi(kc, &ap, &bp, &mut want, NR, MR, NR, epi);
            } else {
                micro_4x8_portable_epi(kc, &ap, &bp, &mut want, NR, MR, NR, epi);
            }
            assert_eq!(
                got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "epilogue kernel drifted from its contract under {epi:?}"
            );
        }
    }

    #[test]
    fn relu_store_normalizes_zeros_and_nan() {
        assert_eq!(relu_store(2.5), 2.5);
        assert_eq!(relu_store(-1.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(relu_store(-0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(relu_store(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(relu_store(f32::NAN).to_bits(), 0.0f32.to_bits());
    }

    #[test]
    fn epilogue_boundary_hits_exact_zero_as_positive_zero() {
        // Construct tile sums that land exactly on ±0 at the ReLU
        // boundary: with kc = 0 the accumulator is +0.0, so the stored
        // value is relu((C + 0) + bias). C = -bias makes the pre-ReLU
        // sum exactly +0.0 (IEEE x + (-x) = +0.0), and a -0.0 bias over
        // a +0.0 C exercises the signed-zero add — every case must
        // store literal +0.0 bits, on the dispatched kernel too.
        let c0 = [0.5f32, -0.5, 0.0, -0.0, 1.0, -1.0, 0.25, -0.25];
        let bias = [-0.5f32, 0.5, -0.0, 0.0, -1.0, 1.0, -0.25, 0.25];
        let ap: [f32; 0] = [];
        let bp: [f32; 0] = [];
        let kernels: [Micro4x8Epi; 3] =
            [micro_4x8_ref_epi, micro_4x8_portable_epi, table().micro_4x8_epi];
        for kernel in kernels {
            let mut c = c0.to_vec();
            kernel(0, &ap, &bp, &mut c, NR, 1, NR, Epilogue::BiasRelu(&bias));
            for (j, v) in c.iter().enumerate() {
                assert_eq!(
                    v.to_bits(),
                    0.0f32.to_bits(),
                    "lane {j}: ReLU boundary produced {v} (bits {:#010x}), want +0.0",
                    v.to_bits()
                );
            }
        }
    }

    #[test]
    fn routing_dot_is_bit_identical_to_scalar_replica() {
        // The dispatched kernel (SIMD where available) must reproduce the
        // scalar lane-striped replica bit for bit on every length,
        // including ragged tails — routing correctness rides on it.
        let mut rng = Rng::seed_from_u64(77);
        let mut a = vec![0.0f32; 301];
        let mut b = vec![0.0f32; 301];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        for n in 1..=301 {
            let got = routing_dot(&a[..n], &b[..n]);
            let want = routing_dot_scalar(&a[..n], &b[..n]);
            assert_eq!(got.to_bits(), want.to_bits(), "lane drift at n={n}");
        }
    }

    #[test]
    fn routing_dot_matches_reference_numerically() {
        let mut rng = Rng::seed_from_u64(78);
        for &n in &[1usize, 5, 16, 17, 64, 300] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            let reference: f64 = a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = routing_dot(&a, &b) as f64;
            assert!((got - reference).abs() < 1e-3, "n={n}: {got} vs {reference}");
        }
    }

    #[test]
    fn micro_ref_and_portable_agree_when_products_are_exact() {
        // With few-significand-bit inputs every product is exact, so the
        // fused and unfused tiles must coincide bit for bit — a cheap
        // cross-check that the two replicas implement the same loop.
        let kc = 11;
        let ap: Vec<f32> = (0..kc * MR).map(|i| (i % 7) as f32 - 3.0).collect();
        let bp: Vec<f32> = (0..kc * NR).map(|i| (i % 5) as f32 * 0.25 - 0.5).collect();
        let mut c1 = vec![0.0f32; MR * 10];
        let mut c2 = vec![0.0f32; MR * 10];
        micro_4x8_ref(kc, &ap, &bp, &mut c1, 10, 3, 7);
        micro_4x8_portable(kc, &ap, &bp, &mut c2, 10, 3, 7);
        assert_eq!(c1, c2);
    }

    #[test]
    fn prefetch_slice_is_a_safe_noop() {
        // Prefetch has no observable effect; this just exercises the
        // pointer arithmetic on ragged lengths under Miri-style review.
        let v = vec![1.0f32; 131];
        prefetch_slice(&v);
        prefetch_slice(&v[..1]);
        prefetch_slice(&[]);
    }
}
