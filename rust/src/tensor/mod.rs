//! Minimal dense-tensor substrate: a row-major `f32` matrix plus the
//! linear-algebra and elementwise kernels the native engine needs.
//!
//! The offline registry has no `ndarray`/`nalgebra`, so this is built from
//! scratch. The GEMM drivers live in [`gemm`] and are one of the §Perf
//! targets (see EXPERIMENTS.md §Perf); the machine kernels they run —
//! explicit AVX2/FMA and NEON microkernels plus the routing dot — are
//! detected and dispatched by [`kernels`], and large products run
//! multi-threaded on the [`pool`] work-stealing thread pool.

mod gemm;
pub mod kernels;
mod ops;
pub mod pool;
pub mod scratch;

pub use gemm::{
    gemm, gemm_acc, gemm_bias, gemm_bias_into, gemm_bias_relu, gemm_bias_relu_into, gemm_into,
    gemm_nt, gemm_nt_acc, gemm_nt_bias_relu, gemm_nt_gather_epi, gemm_nt_into, gemm_packed,
    gemm_packed_gather_epi, gemm_quant_gather_epi, gemm_scalar, gemm_tn, gemm_tn_acc,
    parallel_flop_threshold, set_parallel_flop_threshold, PackedB, QuantPackedB,
};
pub(crate) use gemm::{
    fused_leaf_available, gemm_bias_scatter_raw, gemm_nt_row, gemm_quant_scatter_prequant,
    gemm_quant_scatter_raw, leaf_quant_l1,
};
pub use kernels::{prefetch_slice, relu_store, routing_dot, Epilogue, Precision};
pub use ops::*;

/// Row-major 2-D `f32` tensor. Rows index samples in all batched code.
///
/// Invariant: `data.len() >= rows * cols`. [`Matrix::resize`] is
/// grow-only on the backing buffer, so a retained matrix shrunk for a
/// small batch regrows to a previously-seen size without reallocating
/// *or* re-zeroing (the tail beyond `rows * cols` is retained garbage
/// that no accessor exposes). Equality compares the logical window.
#[derive(Clone, Debug)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Default for Matrix {
    /// The empty `0 × 0` matrix (no backing allocation) — the natural
    /// initial state for retained grow-only buffers.
    fn default() -> Matrix {
        Matrix::zeros(0, 0)
    }
}

impl PartialEq for Matrix {
    fn eq(&self, other: &Matrix) -> bool {
        self.rows == other.rows && self.cols == other.cols && self.as_slice() == other.as_slice()
    }
}

impl Matrix {
    /// All-zeros matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Matrix filled with `value`.
    pub fn full(rows: usize, cols: usize, value: f32) -> Self {
        Matrix { rows, cols, data: vec![value; rows * cols] }
    }

    /// Build from an existing row-major buffer (length must be rows*cols).
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "from_vec: shape/data mismatch");
        Matrix { rows, cols, data }
    }

    /// Build by evaluating `f(r, c)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    #[inline]
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.rows * self.cols
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn as_slice(&self) -> &[f32] {
        &self.data[..self.rows * self.cols]
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f32] {
        &mut self.data[..self.rows * self.cols]
    }

    /// Consume into the underlying row-major buffer (truncated to the
    /// logical `rows * cols` window).
    pub fn into_vec(self) -> Vec<f32> {
        let mut data = self.data;
        data.truncate(self.rows * self.cols);
        data
    }

    /// Immutable view of row `r`. Indexes through the logical window, so
    /// an out-of-range row panics in release builds too — the retained
    /// tail beyond `rows * cols` (see [`Matrix::resize`]) is unreachable.
    #[inline]
    pub fn row(&self, r: usize) -> &[f32] {
        debug_assert!(r < self.rows);
        &self.as_slice()[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable view of row `r` (window-checked like [`Matrix::row`]).
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        debug_assert!(r < self.rows);
        let cols = self.cols;
        &mut self.as_mut_slice()[r * cols..(r + 1) * cols]
    }

    /// Element access. Real asserts, not debug: the flattened index
    /// `r * cols + c` can land inside the window even when `c >= cols`
    /// (it aliases an element of the next row), so unlike [`Matrix::row`]
    /// the slice indexing alone would NOT catch the misuse in release.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        assert!(r < self.rows && c < self.cols, "Matrix::get out of range");
        self.as_slice()[r * self.cols + c]
    }

    /// Element assignment (range-checked like [`Matrix::get`] — a column
    /// overflow would otherwise silently write the next row's element).
    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        assert!(r < self.rows && c < self.cols, "Matrix::set out of range");
        let idx = r * self.cols + c;
        self.as_mut_slice()[idx] = v;
    }

    /// Reshape in place to `rows × cols`. The backing buffer is
    /// **grow-only**: it extends (zero-filling just the new tail) only
    /// when `rows * cols` exceeds every size seen so far, so a retained
    /// serving matrix cycling through fluctuating batch sizes performs
    /// neither allocations nor memsets once it has seen its largest
    /// batch. Contents are **unspecified** after a resize; callers
    /// overwrite every element (the batched inference and serving paths
    /// write every output row).
    pub fn resize(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        if self.data.len() < rows * cols {
            self.data.resize(rows * cols, 0.0);
        }
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on larger matrices.
        const B: usize = 32;
        for rb in (0..self.rows).step_by(B) {
            for cb in (0..self.cols).step_by(B) {
                for r in rb..(rb + B).min(self.rows) {
                    for c in cb..(cb + B).min(self.cols) {
                        out.data[c * self.rows + r] = self.data[r * self.cols + c];
                    }
                }
            }
        }
        out
    }

    /// Gather a subset of rows into a new matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Elementwise map in place.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in self.as_mut_slice() {
            *v = f(*v);
        }
    }

    /// Elementwise map into a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Matrix {
        let mut out = self.clone();
        out.map_inplace(f);
        out
    }

    /// `self += other` (shapes must match).
    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.as_mut_slice().iter_mut().zip(other.as_slice()) {
            *a += b;
        }
    }

    /// `self += alpha * other`.
    pub fn axpy(&mut self, alpha: f32, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.as_mut_slice().iter_mut().zip(other.as_slice()) {
            *a += alpha * b;
        }
    }

    /// `self *= alpha`.
    pub fn scale(&mut self, alpha: f32) {
        for a in self.as_mut_slice() {
            *a *= alpha;
        }
    }

    /// Elementwise product in place.
    pub fn mul_assign_elem(&mut self, other: &Matrix) {
        assert_eq!(self.shape(), other.shape());
        for (a, b) in self.as_mut_slice().iter_mut().zip(other.as_slice()) {
            *a *= b;
        }
    }

    /// Zero all entries (reuse allocation between steps).
    pub fn fill_zero(&mut self) {
        self.as_mut_slice().iter_mut().for_each(|v| *v = 0.0);
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f32 {
        self.as_slice().iter().sum()
    }

    /// Frobenius norm.
    pub fn frobenius(&self) -> f32 {
        self.as_slice().iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Max absolute difference to another matrix (for tests).
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.as_slice()
            .iter()
            .zip(other.as_slice())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_fn(2, 3, |r, c| (r * 3 + c) as f32);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.get(1, 2), 5.0);
        assert_eq!(m.row(1), &[3.0, 4.0, 5.0]);
    }

    #[test]
    #[should_panic]
    fn from_vec_rejects_bad_shape() {
        let _ = Matrix::from_vec(2, 2, vec![1.0; 3]);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = Matrix::from_fn(37, 53, |r, c| (r * 53 + c) as f32);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(5, 7), m.get(7, 5));
    }

    #[test]
    fn gather_rows_picks_rows() {
        let m = Matrix::from_fn(5, 2, |r, _| r as f32);
        let g = m.gather_rows(&[4, 0, 2]);
        assert_eq!(g.as_slice(), &[4.0, 4.0, 0.0, 0.0, 2.0, 2.0]);
    }

    #[test]
    fn resize_reshapes_and_reuses() {
        let mut m = Matrix::from_fn(4, 3, |r, c| (r * 3 + c) as f32);
        let cap = m.data.capacity();
        m.resize(2, 3);
        assert_eq!(m.shape(), (2, 3));
        assert_eq!(m.len(), 6);
        assert_eq!(m.as_slice().len(), 6, "accessors expose the logical window only");
        m.resize(4, 3);
        assert_eq!(m.shape(), (4, 3));
        assert_eq!(m.data.len(), 12, "backing buffer is grow-only (no re-zeroing regrow)");
        assert_eq!(m.data.capacity(), cap, "regrow within capacity must not reallocate");
        // Contents are unspecified after resize; writing works as usual.
        m.row_mut(3).copy_from_slice(&[1.0, 2.0, 3.0]);
        assert_eq!(m.get(3, 2), 3.0);
    }

    #[test]
    fn equality_and_reductions_ignore_retained_tail() {
        // A shrunk matrix keeps garbage beyond rows*cols; equality,
        // sums, and into_vec must all see only the logical window.
        let mut a = Matrix::from_fn(3, 2, |r, c| (r * 2 + c) as f32 + 1.0);
        a.resize(2, 2);
        let b = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a, b);
        assert_eq!(a.sum(), 10.0);
        assert_eq!(a.into_vec(), vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn axpy_and_scale() {
        let mut a = Matrix::full(2, 2, 1.0);
        let b = Matrix::full(2, 2, 2.0);
        a.axpy(0.5, &b);
        assert_eq!(a.as_slice(), &[2.0; 4]);
        a.scale(2.0);
        assert_eq!(a.as_slice(), &[4.0; 4]);
    }

    #[test]
    fn reductions() {
        let m = Matrix::from_vec(1, 4, vec![1.0, -2.0, 3.0, -4.0]);
        assert_eq!(m.sum(), -2.0);
        assert!((m.frobenius() - (30.0f32).sqrt()).abs() < 1e-6);
    }
}
