//! Elementwise / rowwise kernels shared by the native engine.

use super::Matrix;

/// Numerically-stable row-wise softmax, in place.
pub fn softmax_rows_inplace(m: &mut Matrix) {
    let cols = m.cols();
    for r in 0..m.rows() {
        let row = m.row_mut(r);
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
        debug_assert_eq!(row.len(), cols);
    }
}

/// Row-wise softmax into a new matrix.
pub fn softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    softmax_rows_inplace(&mut out);
    out
}

/// Row-wise log-softmax into a new matrix.
pub fn log_softmax_rows(m: &Matrix) -> Matrix {
    let mut out = m.clone();
    for r in 0..out.rows() {
        let row = out.row_mut(r);
        let max = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
        let lse = max + row.iter().map(|&v| (v - max).exp()).sum::<f32>().ln();
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
    out
}

/// Index of the max entry per row.
pub fn argmax_rows(m: &Matrix) -> Vec<usize> {
    let mut out = Vec::new();
    argmax_rows_into(m, &mut out);
    out
}

/// [`argmax_rows`] into a caller-retained buffer (cleared and refilled,
/// reusing capacity — scoring loops stop allocating once warm).
pub fn argmax_rows_into(m: &Matrix, out: &mut Vec<usize>) {
    out.clear();
    out.reserve(m.rows());
    for r in 0..m.rows() {
        let row = m.row(r);
        let mut best = 0;
        let mut bv = row[0];
        for (i, &v) in row.iter().enumerate().skip(1) {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        out.push(best);
    }
}

/// Logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// ReLU forward, in place; returns nothing (mask recoverable from output).
pub fn relu_inplace(m: &mut Matrix) {
    for v in m.as_mut_slice() {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// GELU (tanh approximation), in place.
pub fn gelu_inplace(m: &mut Matrix) {
    for v in m.as_mut_slice() {
        *v = gelu(*v);
    }
}

/// GELU (tanh approximation).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.797_884_56; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Derivative of GELU (tanh approximation).
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.797_884_56;
    let x3 = x * x * x;
    let t = (C * (x + 0.044715 * x3)).tanh();
    let sech2 = 1.0 - t * t;
    0.5 * (1.0 + t) + 0.5 * x * sech2 * C * (1.0 + 3.0 * 0.044715 * x * x)
}

/// Bernoulli entropy H(p) in nats, safe at the endpoints.
#[inline]
pub fn bernoulli_entropy(p: f32) -> f32 {
    let p = p.clamp(1e-7, 1.0 - 1e-7);
    -(p * p.ln() + (1.0 - p) * (1.0 - p).ln())
}

/// Row-wise layer norm (no affine), in place; returns per-row (mean, rstd)
/// needed by the backward pass.
pub fn layernorm_rows_inplace(m: &mut Matrix, eps: f32) -> Vec<(f32, f32)> {
    let cols = m.cols() as f32;
    let mut stats = Vec::with_capacity(m.rows());
    for r in 0..m.rows() {
        let row = m.row_mut(r);
        let mean = row.iter().sum::<f32>() / cols;
        let var = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / cols;
        let rstd = 1.0 / (var + eps).sqrt();
        for v in row.iter_mut() {
            *v = (*v - mean) * rstd;
        }
        stats.push((mean, rstd));
    }
    stats
}

/// Mean of a slice.
#[inline]
pub fn mean(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f32>() / xs.len() as f32
}

/// Dot product of two equal-length slices (4-stripe unrolled). General
/// BLAS-1 helper; the tree descent uses the stricter
/// [`super::kernels::routing_dot`] instead, whose lane order is pinned
/// across ISAs.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let n = a.len();
    let mut p = 0;
    while p + 4 <= n {
        acc0 += a[p] * b[p];
        acc1 += a[p + 1] * b[p + 1];
        acc2 += a[p + 2] * b[p + 2];
        acc3 += a[p + 3] * b[p + 3];
        p += 4;
    }
    while p < n {
        acc0 += a[p] * b[p];
        p += 1;
    }
    acc0 + acc1 + acc2 + acc3
}

/// `y += alpha * x` over slices.
#[inline]
pub fn axpy_slice(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_rows_normalized_and_ordered() {
        let m = Matrix::from_vec(2, 3, vec![1.0, 2.0, 3.0, -1.0, -1.0, -1.0]);
        let s = softmax_rows(&m);
        for r in 0..2 {
            let sum: f32 = s.row(r).iter().sum();
            assert!((sum - 1.0).abs() < 1e-6);
        }
        assert!(s.get(0, 2) > s.get(0, 1) && s.get(0, 1) > s.get(0, 0));
        assert!((s.get(1, 0) - 1.0 / 3.0).abs() < 1e-6);
    }

    #[test]
    fn softmax_stable_at_large_logits() {
        let m = Matrix::from_vec(1, 2, vec![1000.0, 1001.0]);
        let s = softmax_rows(&m);
        assert!(s.as_slice().iter().all(|v| v.is_finite()));
        assert!((s.row(0).iter().sum::<f32>() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn log_softmax_matches_softmax_log() {
        let m = Matrix::from_vec(1, 4, vec![0.3, -1.2, 2.0, 0.0]);
        let ls = log_softmax_rows(&m);
        let s = softmax_rows(&m);
        for j in 0..4 {
            assert!((ls.get(0, j) - s.get(0, j).ln()).abs() < 1e-5);
        }
    }

    #[test]
    fn argmax_rows_finds_max() {
        let m = Matrix::from_vec(2, 3, vec![0.0, 5.0, 1.0, 7.0, 2.0, 3.0]);
        assert_eq!(argmax_rows(&m), vec![1, 0]);
    }

    #[test]
    fn sigmoid_symmetry_and_range() {
        for &x in &[-50.0f32, -3.0, 0.0, 3.0, 50.0] {
            let s = sigmoid(x);
            assert!((0.0..=1.0).contains(&s));
            assert!((s + sigmoid(-x) - 1.0).abs() < 1e-6);
        }
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn entropy_extremes() {
        assert!(bernoulli_entropy(0.5) > bernoulli_entropy(0.9));
        assert!(bernoulli_entropy(0.0) < 1e-5);
        assert!(bernoulli_entropy(1.0) < 1e-5);
        assert!((bernoulli_entropy(0.5) - std::f32::consts::LN_2).abs() < 1e-6);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let h = 1e-3f32;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert!((gelu_grad(x) - fd).abs() < 1e-3, "x={x}: {} vs {fd}", gelu_grad(x));
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut m = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        layernorm_rows_inplace(&mut m, 1e-5);
        let mean: f32 = m.row(0).iter().sum::<f32>() / 4.0;
        let var: f32 = m.row(0).iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert!(mean.abs() < 1e-6);
        assert!((var - 1.0).abs() < 1e-3);
    }

    #[test]
    fn relu_clamps_negatives() {
        let mut m = Matrix::from_vec(1, 4, vec![-1.0, 0.0, 2.0, -3.0]);
        relu_inplace(&mut m);
        assert_eq!(m.as_slice(), &[0.0, 0.0, 2.0, 0.0]);
    }

    #[test]
    fn dot_matches_sum() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = vec![5.0f32, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
    }

    #[test]
    fn axpy_accumulates() {
        let x = vec![1.0f32, -2.0, 0.5];
        let mut y = vec![1.0f32, 1.0, 1.0];
        axpy_slice(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, -3.0, 2.0]);
    }
}
