//! GEMM kernels for the native engine (v2: packed + multi-threaded).
//!
//! Layout is row-major everywhere. Three execution tiers (see
//! EXPERIMENTS.md §Perf for the measured iteration log
//! naive → ikj → packed+parallel):
//!
//! 1. **Small** (below [`parallel_flop_threshold`]): the v1 serial kernel —
//!    classic `i-k-j` loop order with a 4-row unroll and k-blocking; the
//!    innermost loop walks contiguous rows of `B` and `C` and
//!    auto-vectorizes to full-width SIMD. Zero dispatch overhead, so
//!    experiment-scale matrices are not pessimized.
//! 2. **Large**: row bands of `C` are dispatched as work-stealing tasks on
//!    the [`super::pool`] thread pool. Band boundaries never change the
//!    per-element accumulation order, so results are **bit-identical across
//!    thread counts**.
//! 3. Within a band, one of two serial kernels runs, chosen once per
//!    process by a ~1 ms self-calibration (overridable with
//!    `FFF_GEMM_KERNEL=packed|banded`):
//!    * `packed` — `A`/`B` panels packed into cache-blocked buffers and an
//!      explicit 4x8 register-tiled microkernel (the BLIS/matrixmultiply
//!      scheme; wins when the compiler keeps the 4x8 accumulator tile in
//!      SIMD registers);
//!    * `banded` — the v1 `i-k-j` kernel applied per band (wins where the
//!      packed microkernel fails to vectorize; measured on the dev box the
//!      gcc prototype needed this fallback while LLVM vectorizes both).

use super::pool::{self, SendPtr};
use super::Matrix;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::Once;

/// Panel size along `k` — a `KC × NR` micro-panel of `B` (8 KiB) plus a
/// `KC × MR` micro-panel of `A` stays resident in L1.
const KC: usize = 256;
/// Microkernel tile: MR rows of `A` × NR columns of `B`.
const MR: usize = 4;
const NR: usize = 8;

/// 2·m·k·n below which GEMMs stay on the serial v1 kernel. Defaults to
/// 4 MFLOP (~a 128³ product); tune with [`set_parallel_flop_threshold`].
static PAR_FLOP_THRESHOLD: AtomicUsize = AtomicUsize::new(4_000_000);

/// Current FLOP cutoff between the serial small path and the pooled path.
pub fn parallel_flop_threshold() -> usize {
    PAR_FLOP_THRESHOLD.load(Ordering::Relaxed)
}

/// Set the FLOP cutoff (2·m·k·n) above which GEMMs use the thread pool.
/// `0` sends everything through the pooled path (used by tests/benches).
pub fn set_parallel_flop_threshold(flops: usize) {
    PAR_FLOP_THRESHOLD.store(flops, Ordering::Relaxed);
}

/// `C = A (m×k) · B (k×n)`.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_acc(a, b, &mut c);
    c
}

/// `C = A·B + bias` where `bias` is a length-`n` row broadcast over rows.
pub fn gemm_bias(a: &Matrix, b: &Matrix, bias: &[f32]) -> Matrix {
    assert_eq!(bias.len(), b.cols(), "gemm_bias: bias length mismatch");
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for r in 0..c.rows() {
        c.row_mut(r).copy_from_slice(bias);
    }
    gemm_acc(a, b, &mut c);
    c
}

/// `C += A·B` (accumulating GEMM core, auto-dispatched).
pub fn gemm_acc(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "gemm: inner dims {ka} vs {kb}");
    assert_eq!(c.shape(), (m, n), "gemm: output shape");
    let k = ka;
    if 2 * m * k * n < parallel_flop_threshold() {
        seed_kernel(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, k, n);
        return;
    }
    let p = pool::current();
    match kernel_choice() {
        KernelKind::Packed => packed_parallel(a.as_slice(), b.as_slice(), c, m, k, n, &p),
        KernelKind::Banded => banded_parallel(a.as_slice(), b.as_slice(), c, m, k, n, &p),
    }
}

/// `C = A·B` forced through the v1 serial kernel (bench baseline).
pub fn gemm_scalar(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(k, b.rows(), "gemm_scalar: inner dims");
    let mut c = Matrix::zeros(m, n);
    seed_kernel(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, k, n);
    c
}

/// `C = A·B` forced through the packed 4x8 microkernel path on the current
/// pool, regardless of size (property tests and bench suite).
pub fn gemm_packed(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(k, b.rows(), "gemm_packed: inner dims");
    let mut c = Matrix::zeros(m, n);
    let p = pool::current();
    packed_parallel(a.as_slice(), b.as_slice(), &mut c, m, k, n, &p);
    c
}

/// Rows per parallel band: aim for ~4 tasks per thread (work stealing
/// evens out the tail), within [MR, 64], rounded up to a whole number of
/// MR-row micro-panels. Band boundaries do not affect numerics.
fn band_rows(m: usize, threads: usize) -> usize {
    let target = m.div_ceil(threads.max(1) * 4).clamp(MR, 64);
    target.div_ceil(MR) * MR
}

// ---------------------------------------------------------------------------
// Banded path: the v1 i-k-j kernel over pool-dispatched row bands.
// ---------------------------------------------------------------------------

/// The v1 serial kernel: `C += A·B` over raw row-major slices. Per element
/// the accumulation order is `p` ascending within each k-block — identical
/// whether invoked on a full matrix or any row band of it.
fn seed_kernel(av: &[f32], bv: &[f32], cv: &mut [f32], m: usize, k: usize, n: usize) {
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        let mut i = 0;
        // 4-row unrolled macro-kernel.
        while i + 4 <= m {
            let (a0, a1, a2, a3) = (
                &av[i * k..(i + 1) * k],
                &av[(i + 1) * k..(i + 2) * k],
                &av[(i + 2) * k..(i + 3) * k],
                &av[(i + 3) * k..(i + 4) * k],
            );
            for p in k0..k1 {
                let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
                let brow = &bv[p * n..p * n + n];
                let (c01, rest) = cv[i * n..].split_at_mut(2 * n);
                let (c0, c1) = c01.split_at_mut(n);
                let (c2, c3rest) = rest.split_at_mut(n);
                let c3 = &mut c3rest[..n];
                for (j, &bj) in brow.iter().enumerate() {
                    c0[j] += x0 * bj;
                    c1[j] += x1 * bj;
                    c2[j] += x2 * bj;
                    c3[j] += x3 * bj;
                }
            }
            i += 4;
        }
        // Remainder rows.
        while i < m {
            let arow = &av[i * k..(i + 1) * k];
            let crow = &mut cv[i * n..(i + 1) * n];
            for p in k0..k1 {
                let x = arow[p];
                let brow = &bv[p * n..p * n + n];
                for (cj, &bj) in crow.iter_mut().zip(brow) {
                    *cj += x * bj;
                }
            }
            i += 1;
        }
    }
}

/// Row-band parallel wrapper around [`seed_kernel`].
fn banded_parallel(
    av: &[f32],
    bv: &[f32],
    c: &mut Matrix,
    m: usize,
    k: usize,
    n: usize,
    p: &pool::ThreadPool,
) {
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    p.run(n_bands, &|t| {
        let i0 = t * band;
        let rows = band.min(m - i0);
        // SAFETY: bands are disjoint row ranges of `c`, and `run` returns
        // before `c` is touched again by the caller.
        let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        seed_kernel(&av[i0 * k..(i0 + rows) * k], bv, cv, rows, k, n);
    });
}

// ---------------------------------------------------------------------------
// Packed path: cache-blocked panels + explicit 4x8 microkernel.
// ---------------------------------------------------------------------------

/// Pack a `kc`-deep panel of `B` (rows `k0..k0+kc`, all `n` columns) into
/// NR-wide micro-panels: `bpack[jp][p][c]`, zero-padded in the tail panel.
fn pack_b(bv: &[f32], n: usize, k0: usize, kc: usize, bpack: &mut [f32]) {
    let n_panels = n.div_ceil(NR);
    for jp in 0..n_panels {
        let j0 = jp * NR;
        let nr = NR.min(n - j0);
        let dst = &mut bpack[jp * kc * NR..(jp + 1) * kc * NR];
        for p in 0..kc {
            let src = &bv[(k0 + p) * n + j0..(k0 + p) * n + j0 + nr];
            let d = &mut dst[p * NR..p * NR + NR];
            d[..nr].copy_from_slice(src);
            d[nr..].fill(0.0);
        }
    }
}

/// Pack `rows` rows of `A` starting at `i0`, columns `k0..k0+kc`, into
/// MR-tall micro-panels: `apack[ip][p][r]`, zero-padded in the tail panel.
fn pack_a(av: &[f32], k: usize, i0: usize, rows: usize, k0: usize, kc: usize, apack: &mut [f32]) {
    let m_panels = rows.div_ceil(MR);
    for ip in 0..m_panels {
        let r0 = ip * MR;
        let mr = MR.min(rows - r0);
        let dst = &mut apack[ip * kc * MR..(ip + 1) * kc * MR];
        for p in 0..kc {
            let d = &mut dst[p * MR..p * MR + MR];
            for (r, dr) in d[..mr].iter_mut().enumerate() {
                *dr = av[(i0 + r0 + r) * k + k0 + p];
            }
            d[mr..].fill(0.0);
        }
    }
}

/// The 4x8 register-tiled microkernel: `C[mr×nr] += Apanel · Bpanel`.
///
/// Accumulators are four `[f32; NR]` arrays whose addresses are never
/// taken, so the compiler can keep the whole tile in SIMD registers (the
/// prototype showed that forming pointers into them forces a stack spill).
#[inline(always)]
fn kernel_4x8(kc: usize, ap: &[f32], bp: &[f32], cv: &mut [f32], n: usize, mr: usize, nr: usize) {
    let mut acc0 = [0.0f32; NR];
    let mut acc1 = [0.0f32; NR];
    let mut acc2 = [0.0f32; NR];
    let mut acc3 = [0.0f32; NR];
    for p in 0..kc {
        let b: &[f32; NR] = bp[p * NR..(p + 1) * NR].try_into().unwrap();
        let a: &[f32; MR] = ap[p * MR..(p + 1) * MR].try_into().unwrap();
        for (acc, &bc) in acc0.iter_mut().zip(b.iter()) {
            *acc += a[0] * bc;
        }
        for (acc, &bc) in acc1.iter_mut().zip(b.iter()) {
            *acc += a[1] * bc;
        }
        for (acc, &bc) in acc2.iter_mut().zip(b.iter()) {
            *acc += a[2] * bc;
        }
        for (acc, &bc) in acc3.iter_mut().zip(b.iter()) {
            *acc += a[3] * bc;
        }
    }
    if mr > 0 {
        for (cj, &s) in cv[..nr].iter_mut().zip(acc0.iter()) {
            *cj += s;
        }
    }
    if mr > 1 {
        for (cj, &s) in cv[n..n + nr].iter_mut().zip(acc1.iter()) {
            *cj += s;
        }
    }
    if mr > 2 {
        for (cj, &s) in cv[2 * n..2 * n + nr].iter_mut().zip(acc2.iter()) {
            *cj += s;
        }
    }
    if mr > 3 {
        for (cj, &s) in cv[3 * n..3 * n + nr].iter_mut().zip(acc3.iter()) {
            *cj += s;
        }
    }
}

/// Packed serial band: pack the band's rows of `A`, then run the
/// microkernel over every (MR row-panel × NR col-panel) tile.
#[allow(clippy::too_many_arguments)]
fn packed_band(
    av: &[f32],
    bpack: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    k0: usize,
    kc: usize,
) {
    let m_panels = rows.div_ceil(MR);
    let n_panels = n.div_ceil(NR);
    let mut apack = vec![0.0f32; m_panels * MR * kc];
    pack_a(av, k, i0, rows, k0, kc, &mut apack);
    for ip in 0..m_panels {
        let r0 = ip * MR;
        let mr = MR.min(rows - r0);
        let ap = &apack[ip * kc * MR..(ip + 1) * kc * MR];
        for jp in 0..n_panels {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            let bp = &bpack[jp * kc * NR..(jp + 1) * kc * NR];
            kernel_4x8(kc, ap, bp, &mut cv[r0 * n + j0..], n, mr, nr);
        }
    }
}

/// Packed + pooled `C += A·B`: per k-panel, `B` is packed once (shared,
/// read-only) and row bands are dispatched as pool tasks, each packing its
/// own slice of `A` into a thread-local buffer.
fn packed_parallel(
    av: &[f32],
    bv: &[f32],
    c: &mut Matrix,
    m: usize,
    k: usize,
    n: usize,
    p: &pool::ThreadPool,
) {
    let n_panels = n.div_ceil(NR);
    let kc_max = k.min(KC);
    let mut bpack = vec![0.0f32; n_panels * kc_max * NR];
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        pack_b(bv, n, k0, kc, &mut bpack);
        let bp: &[f32] = &bpack[..n_panels * kc * NR];
        p.run(n_bands, &|t| {
            let i0 = t * band;
            let rows = band.min(m - i0);
            // SAFETY: bands are disjoint row ranges of `c`, and `run`
            // returns before `c` is touched again by the caller.
            let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
            packed_band(av, bp, cv, i0, rows, k, n, k0, kc);
        });
    }
}

// ---------------------------------------------------------------------------
// Kernel self-calibration.
// ---------------------------------------------------------------------------

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum KernelKind {
    Packed,
    Banded,
}

static KERNEL_CHOICE: AtomicU8 = AtomicU8::new(0);
static CALIBRATE: Once = Once::new();

/// Which serial kernel the pooled path uses per band. Decided once per
/// process: `FFF_GEMM_KERNEL=packed|banded` wins, otherwise a ~1 ms timing
/// duel on a 64×256×64 product picks the faster one for this build/CPU.
/// (Auto-vectorizers are fickle: the C prototype of the 4x8 microkernel
/// ran 4x faster than i-k-j under LLVM-style codegen but 4x *slower* under
/// gcc without `-ffast-math` — calibrating beats guessing.)
fn kernel_choice() -> KernelKind {
    CALIBRATE.call_once(|| {
        let choice = match std::env::var("FFF_GEMM_KERNEL").as_deref() {
            Ok("packed") => KernelKind::Packed,
            Ok("banded") => KernelKind::Banded,
            _ => calibrate(),
        };
        KERNEL_CHOICE.store(choice as u8 + 1, Ordering::Relaxed);
    });
    if KERNEL_CHOICE.load(Ordering::Relaxed) == KernelKind::Packed as u8 + 1 {
        KernelKind::Packed
    } else {
        KernelKind::Banded
    }
}

fn calibrate() -> KernelKind {
    let (m, k, n) = (64, 256, 64);
    let a = Matrix::from_fn(m, k, |r, c| ((r * 31 + c * 7) % 17) as f32 / 17.0 - 0.5);
    let b = Matrix::from_fn(k, n, |r, c| ((r * 13 + c * 5) % 19) as f32 / 19.0 - 0.5);
    let mut c = Matrix::zeros(m, n);
    let time_min = |f: &mut dyn FnMut()| {
        let mut best = std::time::Duration::MAX;
        for _ in 0..3 {
            let t0 = std::time::Instant::now();
            f();
            best = best.min(t0.elapsed());
        }
        best
    };
    let n_panels = n.div_ceil(NR);
    let mut bpack = vec![0.0f32; n_panels * k * NR];
    pack_b(b.as_slice(), n, 0, k, &mut bpack);
    let t_packed = time_min(&mut || {
        packed_band(a.as_slice(), &bpack, c.as_mut_slice(), 0, m, k, n, 0, k);
    });
    let t_banded = time_min(&mut || {
        seed_kernel(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, k, n);
    });
    if t_packed <= t_banded {
        KernelKind::Packed
    } else {
        KernelKind::Banded
    }
}

// ---------------------------------------------------------------------------
// Transposed variants.
// ---------------------------------------------------------------------------

/// `C = Aᵀ (k×m)ᵀ·B`, i.e. `A` is `k×m` and the result is `m×n`.
/// Used for weight gradients: `dW = Xᵀ · dY`.
///
/// Structured as rank-1 updates `C += a_p ⊗ b_p`. Rows of `A` that are
/// mostly zero (common after ReLU masks) keep a per-element skip; dense
/// rows run branch-free — a branch per element on dense gradients was a
/// measured pessimization. The dense path multiplies by the zeros it no
/// longer skips, which is bit-identical for finite inputs except that
/// `-0.0 + 0.0` normalizes to `+0.0` (and non-finite `B` rows propagate
/// NaN where the skip used to mask them).
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "gemm_tn: inner dims");
    let mut c = Matrix::zeros(m, n);
    let av = a.as_slice();
    let bv = b.as_slice();
    // Per-row sparsity census: one pass over A decides, row by row,
    // whether the skip loop or the dense loop runs.
    let mostly_zero: Vec<bool> = (0..k)
        .map(|p| {
            let zeros = av[p * m..(p + 1) * m].iter().filter(|&&x| x == 0.0).count();
            2 * zeros >= m
        })
        .collect();
    let p = pool::current();
    if 2 * m * k * n < parallel_flop_threshold() || p.threads() == 1 {
        gemm_tn_band(av, bv, c.as_mut_slice(), 0, m, k, m, n, &mostly_zero);
        return c;
    }
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    let mz: &[bool] = &mostly_zero;
    p.run(n_bands, &|t| {
        let i0 = t * band;
        let rows = band.min(m - i0);
        // SAFETY: disjoint row bands of `c`; `run` blocks until done.
        let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        gemm_tn_band(av, bv, cv, i0, rows, k, m, n, mz);
    });
    c
}

/// Rank-1-update band: `C[i0..i0+rows] += Σ_p a_p[i0..] ⊗ b_p`. The `p`
/// loop stays outermost so per-element accumulation order matches the
/// serial kernel exactly (thread-count-invariant results).
#[allow(clippy::too_many_arguments)]
fn gemm_tn_band(
    av: &[f32],
    bv: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    m: usize,
    n: usize,
    mostly_zero: &[bool],
) {
    for p in 0..k {
        let arow = &av[p * m + i0..p * m + i0 + rows];
        let brow = &bv[p * n..(p + 1) * n];
        if mostly_zero[p] {
            for (i, &x) in arow.iter().enumerate() {
                if x == 0.0 {
                    continue; // skip loop: row is mostly ReLU zeros
                }
                axpy_slice(x, brow, &mut cv[i * n..(i + 1) * n]);
            }
        } else {
            for (i, &x) in arow.iter().enumerate() {
                axpy_slice(x, brow, &mut cv[i * n..(i + 1) * n]);
            }
        }
    }
}

/// `C = A (m×k) · Bᵀ` where `B` is `n×k`. Used for input gradients:
/// `dX = dY · Wᵀ` with `W` stored `k_in×k_out`… kept general.
///
/// Each output row is a bundle of dot products, computed independently —
/// row-band dispatch is trivially bit-identical to the serial loop.
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(k, kb, "gemm_nt: inner dims");
    let mut c = Matrix::zeros(m, n);
    let av = a.as_slice();
    let bv = b.as_slice();
    let p = pool::current();
    if 2 * m * k * n < parallel_flop_threshold() || p.threads() == 1 {
        gemm_nt_band(av, bv, c.as_mut_slice(), 0, m, k, n);
        return c;
    }
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    p.run(n_bands, &|t| {
        let i0 = t * band;
        let rows = band.min(m - i0);
        // SAFETY: disjoint row bands of `c`; `run` blocks until done.
        let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        gemm_nt_band(av, bv, cv, i0, rows, k, n);
    });
    c
}

/// Dot-product band with 4 B-rows per pass over each A row (¼ the A-row
/// traffic, 4 independent dot chains — §Perf iteration 1).
fn gemm_nt_band(
    av: &[f32],
    bv: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
) {
    for i in 0..rows {
        let arow = &av[(i0 + i) * k..(i0 + i + 1) * k];
        let crow = &mut cv[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &bv[j * k..(j + 1) * k];
            let b1 = &bv[(j + 1) * k..(j + 2) * k];
            let b2 = &bv[(j + 2) * k..(j + 3) * k];
            let b3 = &bv[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (p, &x) in arow.iter().enumerate() {
                s0 += x * b0[p];
                s1 += x * b1[p];
                s2 += x * b2[p];
                s3 += x * b3[p];
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            crow[j + 2] = s2;
            crow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            crow[j] = dot(arow, &bv[j * k..(j + 1) * k]);
            j += 1;
        }
    }
}

/// Dot product of two equal-length slices (unrolled).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let n = a.len();
    let mut p = 0;
    while p + 4 <= n {
        acc0 += a[p] * b[p];
        acc1 += a[p + 1] * b[p + 1];
        acc2 += a[p + 2] * b[p + 2];
        acc3 += a[p + 3] * b[p + 3];
        p += 4;
    }
    while p < n {
        acc0 += a[p] * b[p];
        p += 1;
    }
    acc0 + acc1 + acc2 + acc3
}

/// `y += alpha * x` over slices.
#[inline]
pub fn axpy_slice(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

// ---------------------------------------------------------------------------
// Routing dot product (the tree-descent kernel).
// ---------------------------------------------------------------------------

/// Stripe width of the routing dot: 16 independent accumulator lanes
/// (two 8-wide SIMD chains on AVX), reduced by a fixed pairwise tree.
const RDOT_LANES: usize = 16;

/// The boundary-logit dot product every tree-descent path uses.
///
/// Fixed numerics: products are accumulated into [`RDOT_LANES`] independent
/// lanes (`lane = p mod 16`) and reduced by a fixed pairwise tree, using
/// separate multiply and add (never FMA). The explicit-SIMD path and the
/// scalar path perform the *same* IEEE operations in the *same* order, so
/// [`routing_dot`] is bit-identical across ISAs, batch shapes, and thread
/// counts — which is what lets `route`, `route_batch`, and the training
/// model's `leaf_index` guarantee identical descent decisions (a logit on
/// the wrong side of zero would silently route to a different leaf).
///
/// This is also the §Perf "explicit SIMD" answer for the descent: the
/// auto-vectorizer keeps [`dot`]'s 4-stripe form at 4 lanes, while the
/// explicit 2×8-lane kernel measured 2–3x faster per descent level (see
/// EXPERIMENTS.md §Perf, batched tree descent).
#[inline]
pub fn routing_dot(a: &[f32], b: &[f32]) -> f32 {
    #[cfg(target_arch = "x86_64")]
    {
        if avx_available() {
            // SAFETY: the `avx` feature was verified at runtime.
            return unsafe { routing_dot_avx(a, b) };
        }
    }
    routing_dot_scalar(a, b)
}

/// Fixed reduction tree over the 16 accumulator lanes.
#[inline]
fn rdot_reduce(acc: &[f32; RDOT_LANES]) -> f32 {
    let s0 = (acc[0] + acc[1]) + (acc[2] + acc[3]);
    let s1 = (acc[4] + acc[5]) + (acc[6] + acc[7]);
    let s2 = (acc[8] + acc[9]) + (acc[10] + acc[11]);
    let s3 = (acc[12] + acc[13]) + (acc[14] + acc[15]);
    (s0 + s1) + (s2 + s3)
}

/// Scalar replica of the SIMD routing dot (same lanes, same order).
fn routing_dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let mut acc = [0.0f32; RDOT_LANES];
    let mut p = 0;
    while p + RDOT_LANES <= n {
        for q in 0..RDOT_LANES {
            acc[q] += a[p + q] * b[p + q];
        }
        p += RDOT_LANES;
    }
    while p < n {
        acc[p % RDOT_LANES] += a[p] * b[p];
        p += 1;
    }
    rdot_reduce(&acc)
}

/// Runtime AVX detection, cached (0 = unknown, 1 = no, 2 = yes).
#[cfg(target_arch = "x86_64")]
fn avx_available() -> bool {
    static AVX: AtomicU8 = AtomicU8::new(0);
    match AVX.load(Ordering::Relaxed) {
        2 => true,
        1 => false,
        _ => {
            let yes = std::arch::is_x86_feature_detected!("avx");
            AVX.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

/// Two 8-wide mul+add chains; bit-identical to [`routing_dot_scalar`]
/// because each SIMD lane is an independent IEEE add chain and the
/// writeback feeds the same fixed reduction tree.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx")]
unsafe fn routing_dot_avx(a: &[f32], b: &[f32]) -> f32 {
    use std::arch::x86_64::{
        _mm256_add_ps, _mm256_loadu_ps, _mm256_mul_ps, _mm256_setzero_ps, _mm256_storeu_ps,
    };
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let ap = a.as_ptr();
    let bp = b.as_ptr();
    let mut acc0 = _mm256_setzero_ps();
    let mut acc1 = _mm256_setzero_ps();
    let mut p = 0usize;
    while p + RDOT_LANES <= n {
        let prod0 = _mm256_mul_ps(_mm256_loadu_ps(ap.add(p)), _mm256_loadu_ps(bp.add(p)));
        let prod1 = _mm256_mul_ps(_mm256_loadu_ps(ap.add(p + 8)), _mm256_loadu_ps(bp.add(p + 8)));
        acc0 = _mm256_add_ps(acc0, prod0);
        acc1 = _mm256_add_ps(acc1, prod1);
        p += RDOT_LANES;
    }
    let mut acc = [0.0f32; RDOT_LANES];
    _mm256_storeu_ps(acc.as_mut_ptr(), acc0);
    _mm256_storeu_ps(acc.as_mut_ptr().add(8), acc1);
    while p < n {
        acc[p % RDOT_LANES] += a[p] * b[p];
        p += 1;
    }
    rdot_reduce(&acc)
}

/// Prefetch a weight row the descent will need a few samples from now.
///
/// The level-synchronous router knows every sample's next node row up
/// front (unlike the dependent per-sample walk, whose next address exists
/// only after the current dot resolves), so it can hide DRAM latency on
/// deep, larger-than-cache levels. No-op on non-x86_64 targets.
#[inline]
pub fn prefetch_slice(row: &[f32]) {
    #[cfg(target_arch = "x86_64")]
    {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T1};
        let ptr = row.as_ptr();
        let mut p = 0usize;
        // One prefetch per 64-byte line.
        while p < row.len() {
            // SAFETY: `ptr + p` stays inside `row`; prefetch cannot fault.
            unsafe { _mm_prefetch::<_MM_HINT_T1>(ptr.add(p) as *const i8) };
            p += 16;
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        let _ = row;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a.get(i, p) as f64 * b.get(p, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(m.as_mut_slice(), 0.0, 1.0);
        m
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Rng::seed_from_u64(1);
        let shapes = [(1, 1, 1), (3, 5, 7), (4, 4, 4), (17, 33, 9), (64, 300, 10), (5, 1, 5)];
        for &(m, k, n) in &shapes {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let c = gemm(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-3, "({m},{k},{n}) diff={}", c.max_abs_diff(&c0));
        }
    }

    #[test]
    fn gemm_packed_matches_naive_ragged_shapes() {
        let mut rng = Rng::seed_from_u64(11);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (17, 33, 9),
            (33, 257, 31),
            (65, 513, 129),
            (128, 64, 8),
            (31, 300, 17),
            (5, 1, 5),
        ] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let c = gemm_packed(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-3, "({m},{k},{n}) diff={}", c.max_abs_diff(&c0));
        }
    }

    #[test]
    fn pooled_paths_are_thread_count_invariant() {
        use crate::tensor::pool::{set_current, ThreadPool};
        use std::sync::Arc;
        let mut rng = Rng::seed_from_u64(12);
        let a = rand_mat(&mut rng, 70, 130);
        let b = rand_mat(&mut rng, 130, 50);
        let serial = {
            set_current(Some(Arc::new(ThreadPool::new(1))));
            let c = gemm_packed(&a, &b);
            set_current(None);
            c
        };
        for threads in [2usize, 4, 8] {
            set_current(Some(Arc::new(ThreadPool::new(threads))));
            let c = gemm_packed(&a, &b);
            set_current(None);
            assert_eq!(c, serial, "packed path drifted at {threads} threads");
        }
    }

    #[test]
    fn banded_parallel_is_bit_identical_to_scalar() {
        use crate::tensor::pool::ThreadPool;
        let mut rng = Rng::seed_from_u64(13);
        let a = rand_mat(&mut rng, 67, 90);
        let b = rand_mat(&mut rng, 90, 41);
        let want = gemm_scalar(&a, &b);
        for threads in [1usize, 3, 4] {
            let p = ThreadPool::new(threads);
            let mut c = Matrix::zeros(67, 41);
            banded_parallel(a.as_slice(), b.as_slice(), &mut c, 67, 90, 41, &p);
            assert_eq!(c, want, "banded path diverged from the v1 kernel at {threads} threads");
        }
    }

    #[test]
    fn gemm_bias_adds_bias() {
        let mut rng = Rng::seed_from_u64(2);
        let a = rand_mat(&mut rng, 6, 4);
        let b = rand_mat(&mut rng, 4, 3);
        let bias = vec![1.0, -2.0, 0.5];
        let c = gemm_bias(&a, &b, &bias);
        let mut c0 = naive(&a, &b);
        for r in 0..6 {
            for j in 0..3 {
                c0.set(r, j, c0.get(r, j) + bias[j]);
            }
        }
        assert!(c.max_abs_diff(&c0) < 1e-4);
    }

    #[test]
    fn gemm_tn_matches_transpose() {
        let mut rng = Rng::seed_from_u64(3);
        let a = rand_mat(&mut rng, 13, 7); // k×m
        let b = rand_mat(&mut rng, 13, 5); // k×n
        let c = gemm_tn(&a, &b);
        let c0 = naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&c0) < 1e-3);
    }

    #[test]
    fn gemm_tn_sparse_and_dense_rows_agree() {
        // Mix fully-dense rows with ReLU-style sparse rows so both the
        // skip loop and the branch-free loop run; compare to the naive
        // transpose oracle.
        let mut rng = Rng::seed_from_u64(31);
        let mut a = rand_mat(&mut rng, 40, 23); // k×m
        for p in 0..40 {
            if p % 2 == 0 {
                for v in a.row_mut(p).iter_mut() {
                    if *v < 0.4 {
                        *v = 0.0; // mostly-zero row → skip loop
                    }
                }
            }
        }
        let b = rand_mat(&mut rng, 40, 11);
        let c = gemm_tn(&a, &b);
        let c0 = naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&c0) < 1e-3, "diff={}", c.max_abs_diff(&c0));
    }

    #[test]
    fn gemm_nt_matches_transpose() {
        let mut rng = Rng::seed_from_u64(4);
        let a = rand_mat(&mut rng, 9, 11); // m×k
        let b = rand_mat(&mut rng, 6, 11); // n×k
        let c = gemm_nt(&a, &b);
        let c0 = naive(&a, &b.transpose());
        assert!(c.max_abs_diff(&c0) < 1e-3);
    }

    #[test]
    fn dot_matches_sum() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = vec![5.0f32, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
    }

    #[test]
    fn routing_dot_is_bit_identical_to_scalar_replica() {
        // The dispatched kernel (SIMD where available) must reproduce the
        // scalar lane-striped replica bit for bit on every length,
        // including ragged tails — routing correctness rides on it.
        let mut rng = Rng::seed_from_u64(77);
        let mut a = vec![0.0f32; 301];
        let mut b = vec![0.0f32; 301];
        rng.fill_normal(&mut a, 0.0, 1.0);
        rng.fill_normal(&mut b, 0.0, 1.0);
        for n in 1..=301 {
            let got = routing_dot(&a[..n], &b[..n]);
            let want = routing_dot_scalar(&a[..n], &b[..n]);
            assert_eq!(got.to_bits(), want.to_bits(), "lane drift at n={n}");
        }
    }

    #[test]
    fn routing_dot_matches_reference_numerically() {
        let mut rng = Rng::seed_from_u64(78);
        for &n in &[1usize, 5, 16, 17, 64, 300] {
            let mut a = vec![0.0f32; n];
            let mut b = vec![0.0f32; n];
            rng.fill_normal(&mut a, 0.0, 1.0);
            rng.fill_normal(&mut b, 0.0, 1.0);
            let reference: f64 =
                a.iter().zip(&b).map(|(&x, &y)| x as f64 * y as f64).sum();
            let got = routing_dot(&a, &b) as f64;
            assert!((got - reference).abs() < 1e-3, "n={n}: {got} vs {reference}");
        }
    }

    #[test]
    fn prefetch_slice_is_a_safe_noop() {
        // Prefetch has no observable effect; this just exercises the
        // pointer arithmetic on ragged lengths under Miri-style review.
        let v = vec![1.0f32; 131];
        prefetch_slice(&v);
        prefetch_slice(&v[..1]);
        prefetch_slice(&[]);
    }

    #[test]
    fn gemm_acc_accumulates() {
        let mut rng = Rng::seed_from_u64(5);
        let a = rand_mat(&mut rng, 8, 8);
        let b = rand_mat(&mut rng, 8, 8);
        let mut c = gemm(&a, &b);
        gemm_acc(&a, &b, &mut c);
        let mut c2 = gemm(&a, &b);
        c2.scale(2.0);
        assert!(c.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    fn threshold_is_tunable() {
        let before = parallel_flop_threshold();
        set_parallel_flop_threshold(123);
        assert_eq!(parallel_flop_threshold(), 123);
        set_parallel_flop_threshold(before);
    }
}
