//! GEMM kernels for the native engine.
//!
//! Layout is row-major everywhere. The main kernel uses the classic
//! `i-k-j` loop order with a 4-row unroll: the innermost loop walks
//! contiguous rows of `B` and `C`, which LLVM auto-vectorizes to full-width
//! SIMD on this target. K-blocking keeps the working set of `B` in L1/L2.
//!
//! This file is a §Perf target; see EXPERIMENTS.md §Perf for the measured
//! iteration log (naive → ikj → 4-row unroll + k-blocking).

use super::Matrix;

/// Panel size along `k` — chosen so a `KB × cols(B)` panel of `B` stays
/// resident in L2 for the matrix sizes the experiments use.
const KB: usize = 256;

/// `C = A (m×k) · B (k×n)`.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_acc(a, b, &mut c);
    c
}

/// `C = A·B + bias` where `bias` is a length-`n` row broadcast over rows.
pub fn gemm_bias(a: &Matrix, b: &Matrix, bias: &[f32]) -> Matrix {
    assert_eq!(bias.len(), b.cols(), "gemm_bias: bias length mismatch");
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for r in 0..c.rows() {
        c.row_mut(r).copy_from_slice(bias);
    }
    gemm_acc(a, b, &mut c);
    c
}

/// `C += A·B` (accumulating GEMM core).
pub fn gemm_acc(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "gemm: inner dims {ka} vs {kb}");
    assert_eq!(c.shape(), (m, n), "gemm: output shape");
    let k = ka;

    let av = a.as_slice();
    let bv = b.as_slice();
    let cv = c.as_mut_slice();

    for k0 in (0..k).step_by(KB) {
        let k1 = (k0 + KB).min(k);
        let mut i = 0;
        // 4-row unrolled macro-kernel.
        while i + 4 <= m {
            let (a0, a1, a2, a3) = (
                &av[i * k..(i + 1) * k],
                &av[(i + 1) * k..(i + 2) * k],
                &av[(i + 2) * k..(i + 3) * k],
                &av[(i + 3) * k..(i + 4) * k],
            );
            for p in k0..k1 {
                let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
                let brow = &bv[p * n..p * n + n];
                let (c01, rest) = cv[i * n..].split_at_mut(2 * n);
                let (c0, c1) = c01.split_at_mut(n);
                let (c2, c3rest) = rest.split_at_mut(n);
                let c3 = &mut c3rest[..n];
                for j in 0..n {
                    let bj = brow[j];
                    c0[j] += x0 * bj;
                    c1[j] += x1 * bj;
                    c2[j] += x2 * bj;
                    c3[j] += x3 * bj;
                }
            }
            i += 4;
        }
        // Remainder rows.
        while i < m {
            let arow = &av[i * k..(i + 1) * k];
            let crow = &mut cv[i * n..(i + 1) * n];
            for p in k0..k1 {
                let x = arow[p];
                let brow = &bv[p * n..p * n + n];
                for j in 0..n {
                    crow[j] += x * brow[j];
                }
            }
            i += 1;
        }
    }
}

/// `C = Aᵀ (k×m)ᵀ·B`, i.e. `A` is `k×m` and the result is `m×n`.
/// Used for weight gradients: `dW = Xᵀ · dY`.
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "gemm_tn: inner dims");
    let mut c = Matrix::zeros(m, n);
    let av = a.as_slice();
    let bv = b.as_slice();
    let cv = c.as_mut_slice();
    // For each sample p, rank-1 update C += a_p ⊗ b_p; inner loop is
    // contiguous over both B's row and C's row.
    for p in 0..k {
        let arow = &av[p * m..(p + 1) * m];
        let brow = &bv[p * n..(p + 1) * n];
        for i in 0..m {
            let x = arow[i];
            if x == 0.0 {
                continue; // common after ReLU masks
            }
            let crow = &mut cv[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += x * brow[j];
            }
        }
    }
    c
}

/// `C = A (m×k) · Bᵀ` where `B` is `n×k`. Used for input gradients:
/// `dX = dY · Wᵀ` with `W` stored `k_in×k_out`… kept general.
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(k, kb, "gemm_nt: inner dims");
    let mut c = Matrix::zeros(m, n);
    let av = a.as_slice();
    let bv = b.as_slice();
    let cv = c.as_mut_slice();
    for i in 0..m {
        let arow = &av[i * k..(i + 1) * k];
        let crow = &mut cv[i * n..(i + 1) * n];
        // Register blocking: 4 B-rows per pass over arow (¼ the arow
        // traffic, 4 independent dot chains) — §Perf iteration 1.
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &bv[j * k..(j + 1) * k];
            let b1 = &bv[(j + 1) * k..(j + 2) * k];
            let b2 = &bv[(j + 2) * k..(j + 3) * k];
            let b3 = &bv[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for p in 0..k {
                let x = arow[p];
                s0 += x * b0[p];
                s1 += x * b1[p];
                s2 += x * b2[p];
                s3 += x * b3[p];
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            crow[j + 2] = s2;
            crow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            crow[j] = dot(arow, &bv[j * k..(j + 1) * k]);
            j += 1;
        }
    }
    c
}

/// Dot product of two equal-length slices (unrolled).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc0 = 0.0f32;
    let mut acc1 = 0.0f32;
    let mut acc2 = 0.0f32;
    let mut acc3 = 0.0f32;
    let n = a.len();
    let mut p = 0;
    while p + 4 <= n {
        acc0 += a[p] * b[p];
        acc1 += a[p + 1] * b[p + 1];
        acc2 += a[p + 2] * b[p + 2];
        acc3 += a[p + 3] * b[p + 3];
        p += 4;
    }
    while p < n {
        acc0 += a[p] * b[p];
        p += 1;
    }
    acc0 + acc1 + acc2 + acc3
}

/// `y += alpha * x` over slices.
#[inline]
pub fn axpy_slice(alpha: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += alpha * xi;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a.get(i, p) as f64 * b.get(p, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(m.as_mut_slice(), 0.0, 1.0);
        m
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Rng::seed_from_u64(1);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (4, 4, 4), (17, 33, 9), (64, 300, 10), (5, 1, 5)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let c = gemm(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-3, "({m},{k},{n}) diff={}", c.max_abs_diff(&c0));
        }
    }

    #[test]
    fn gemm_bias_adds_bias() {
        let mut rng = Rng::seed_from_u64(2);
        let a = rand_mat(&mut rng, 6, 4);
        let b = rand_mat(&mut rng, 4, 3);
        let bias = vec![1.0, -2.0, 0.5];
        let c = gemm_bias(&a, &b, &bias);
        let mut c0 = naive(&a, &b);
        for r in 0..6 {
            for j in 0..3 {
                c0.set(r, j, c0.get(r, j) + bias[j]);
            }
        }
        assert!(c.max_abs_diff(&c0) < 1e-4);
    }

    #[test]
    fn gemm_tn_matches_transpose() {
        let mut rng = Rng::seed_from_u64(3);
        let a = rand_mat(&mut rng, 13, 7); // k×m
        let b = rand_mat(&mut rng, 13, 5); // k×n
        let c = gemm_tn(&a, &b);
        let c0 = naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&c0) < 1e-3);
    }

    #[test]
    fn gemm_nt_matches_transpose() {
        let mut rng = Rng::seed_from_u64(4);
        let a = rand_mat(&mut rng, 9, 11); // m×k
        let b = rand_mat(&mut rng, 6, 11); // n×k
        let c = gemm_nt(&a, &b);
        let c0 = naive(&a, &b.transpose());
        assert!(c.max_abs_diff(&c0) < 1e-3);
    }

    #[test]
    fn dot_matches_sum() {
        let a = vec![1.0f32, 2.0, 3.0, 4.0, 5.0];
        let b = vec![5.0f32, 4.0, 3.0, 2.0, 1.0];
        assert_eq!(dot(&a, &b), 35.0);
    }

    #[test]
    fn gemm_acc_accumulates() {
        let mut rng = Rng::seed_from_u64(5);
        let a = rand_mat(&mut rng, 8, 8);
        let b = rand_mat(&mut rng, 8, 8);
        let mut c = gemm(&a, &b);
        gemm_acc(&a, &b, &mut c);
        let mut c2 = gemm(&a, &b);
        c2.scale(2.0);
        assert!(c.max_abs_diff(&c2) < 1e-4);
    }
}
