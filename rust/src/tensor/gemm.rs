//! GEMM drivers for the native engine (v6: the int8 quantized serving
//! path — [`QuantPackedB`] per-panel-scaled weights, on-the-fly A-row
//! quantization, i32-tile microkernels with a dequantizing epilogue
//! store, see EXPERIMENTS.md §Perf iteration 6; v5 added caller-retained
//! `_into` and accumulating `_acc` forms for the level-batched training
//! engine; v4 fused store-phase epilogues, the prepacked-B serving path,
//! and scratch-arena pack buffers; v3 the explicit-SIMD microkernel).
//!
//! Layout is row-major everywhere. Execution tiers (see EXPERIMENTS.md
//! §Perf for the measured iteration log naive → ikj → packed+parallel →
//! intrinsic microkernel):
//!
//! 1. **Small** (below [`parallel_flop_threshold`]) or kind `serial`: the
//!    v1 serial kernel — classic `i-k-j` loop order with a 4-row unroll
//!    and k-blocking; the innermost loop walks contiguous rows of `B` and
//!    `C` and auto-vectorizes. Zero dispatch overhead, so
//!    experiment-scale matrices are not pessimized.
//! 2. **Large**: row bands of `C` are dispatched as work-stealing tasks on
//!    the [`super::pool`] thread pool. Band boundaries never change the
//!    per-element accumulation order, so results are **bit-identical
//!    across thread counts** for every kernel kind.
//! 3. Within a band, the strategy is [`kernels::active`]
//!    (`FFF_GEMM_KERNEL=packed|banded|serial` overrides, tests force it
//!    per case):
//!    * `packed` (default) — `A`/`B` panels packed into cache-blocked
//!      buffers and the 4x8 microkernel from the detected
//!      [`kernels::table`]: explicit AVX2/FMA or NEON intrinsics, with
//!      the auto-vectorized tile as the portable fallback;
//!    * `banded` — the v1 `i-k-j` kernel applied per band (kept as the
//!      comparison baseline and for hosts where packing buys nothing).
//!
//!    The packed-vs-banded runtime calibration from iteration 2 is gone:
//!    it existed because auto-vectorizers disagreed 4x on the
//!    microkernel, and the intrinsic tile removed that variance
//!    (EXPERIMENTS.md §Perf iteration 3).

use super::kernels::{self, Epilogue, KernelKind, MR, NR, QK};
use super::ops::{axpy_slice, dot};
use super::pool::{self, SendPtr};
use super::scratch;
use super::Matrix;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Panel size along `k` — a `KC × NR` micro-panel of `B` (8 KiB) plus a
/// `KC × MR` micro-panel of `A` stays resident in L1.
const KC: usize = 256;

/// 2·m·k·n below which GEMMs stay on the serial v1 kernel. Defaults to
/// 4 MFLOP (~a 128³ product); tune with [`set_parallel_flop_threshold`].
static PAR_FLOP_THRESHOLD: AtomicUsize = AtomicUsize::new(4_000_000);

/// Current FLOP cutoff between the serial small path and the pooled path.
pub fn parallel_flop_threshold() -> usize {
    PAR_FLOP_THRESHOLD.load(Ordering::Relaxed)
}

/// Set the FLOP cutoff (2·m·k·n) above which GEMMs use the thread pool.
/// `0` sends everything through the pooled path (used by tests/benches).
pub fn set_parallel_flop_threshold(flops: usize) {
    PAR_FLOP_THRESHOLD.store(flops, Ordering::Relaxed);
}

/// `C = A (m×k) · B (k×n)`.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_acc(a, b, &mut c);
    c
}

/// `C = A·B + bias` where `bias` is a length-`n` row broadcast over rows.
///
/// v4 numerics: the bias is applied in the **store phase** of the last
/// k-panel (packed path's `_epi` microkernel) or as an elementwise pass
/// after accumulation (banded/serial) — per element `(Σ_p a·b) + bias[j]`,
/// exactly the order a separate bias pass over a [`gemm`] result
/// produces, so the fused and unfused forms are bit-identical kind by
/// kind and thread count by thread count. (The former bias-*initialized*
/// form `((bias + acc₀) + acc₁)…` differed from the separate-pass order
/// by final-rounding ulps; every kind now shares the epilogue-last
/// order.)
pub fn gemm_bias(a: &Matrix, b: &Matrix, bias: &[f32]) -> Matrix {
    gemm_epi(a, b, Epilogue::Bias(bias))
}

/// `C = relu(A·B + bias)` with the ReLU fused into the same store —
/// [`kernels::relu_store`] semantics (`-0.0` and NaN normalize to
/// `+0.0`). One pass over `C` instead of GEMM + bias pass + ReLU pass;
/// at thin-`k` shapes (a leaf's second GEMM, an FF layer with narrow
/// hidden width) the saved passes are a measurable fraction of the whole
/// product (EXPERIMENTS.md §Perf iteration 4).
pub fn gemm_bias_relu(a: &Matrix, b: &Matrix, bias: &[f32]) -> Matrix {
    gemm_epi(a, b, Epilogue::BiasRelu(bias))
}

/// [`gemm`] into a caller-retained output (`c` is resized — grow-only —
/// zeroed, and fully overwritten). The level-batched training engine's
/// form: steady-state steps reuse one output matrix per consumer and
/// stop allocating (tests/alloc_regression.rs).
pub fn gemm_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    gemm_epi_into(a, b, Epilogue::None, c)
}

/// [`gemm_bias`] into a caller-retained output (see [`gemm_into`]).
pub fn gemm_bias_into(a: &Matrix, b: &Matrix, bias: &[f32], c: &mut Matrix) {
    gemm_epi_into(a, b, Epilogue::Bias(bias), c)
}

/// [`gemm_bias_relu`] into a caller-retained output (see
/// [`gemm_bias_into`]).
pub fn gemm_bias_relu_into(a: &Matrix, b: &Matrix, bias: &[f32], c: &mut Matrix) {
    gemm_epi_into(a, b, Epilogue::BiasRelu(bias), c)
}

/// Shared epilogue-fused driver behind [`gemm_bias`]/[`gemm_bias_relu`]:
/// the [`gemm_acc`] dispatch (serial seed kernel below the FLOP
/// threshold, pooled banded/packed above) with `epi` applied exactly
/// once per element after its full accumulation.
fn gemm_epi(a: &Matrix, b: &Matrix, epi: Epilogue) -> Matrix {
    let mut c = Matrix::zeros(0, 0);
    gemm_epi_into(a, b, epi, &mut c);
    c
}

/// [`gemm_epi`] into a caller-retained `c` (resized and zeroed here — the
/// accumulating kernels require a zero start).
fn gemm_epi_into(a: &Matrix, b: &Matrix, epi: Epilogue, c: &mut Matrix) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "gemm: inner dims {ka} vs {kb}");
    if let Epilogue::Bias(bb) | Epilogue::BiasRelu(bb) = epi {
        assert_eq!(bb.len(), n, "gemm: bias length mismatch");
    }
    c.resize(m, n);
    c.fill_zero();
    let k = ka;
    if k == 0 {
        // No k-panels would run, so apply the epilogue directly.
        epilogue_pass(c.as_mut_slice(), m, n, epi);
        return;
    }
    let kind = kernels::active();
    if kind == KernelKind::Serial || 2 * m * k * n < parallel_flop_threshold() {
        seed_kernel(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, k, n);
        epilogue_pass(c.as_mut_slice(), m, n, epi);
        return;
    }
    let p = pool::current();
    match kind {
        KernelKind::Packed => packed_parallel_epi(a.as_slice(), b.as_slice(), c, m, k, n, &p, epi),
        KernelKind::Banded => banded_parallel_epi(a.as_slice(), b.as_slice(), c, m, k, n, &p, epi),
        KernelKind::Serial => unreachable!("serial handled above"),
    }
}

/// Elementwise epilogue over an already-accumulated row-major band — the
/// unfused form, bit-identical to the fused stores (both compute
/// `epi(accumulated_value)` per element, in the same order).
fn epilogue_pass(cv: &mut [f32], rows: usize, n: usize, epi: Epilogue) {
    if matches!(epi, Epilogue::None) {
        return;
    }
    for r in 0..rows {
        for (j, v) in cv[r * n..(r + 1) * n].iter_mut().enumerate() {
            *v = epi.apply(j, *v);
        }
    }
}

/// `C += A·B` (accumulating GEMM core, auto-dispatched).
pub fn gemm_acc(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "gemm: inner dims {ka} vs {kb}");
    assert_eq!(c.shape(), (m, n), "gemm: output shape");
    let k = ka;
    let kind = kernels::active();
    if kind == KernelKind::Serial || 2 * m * k * n < parallel_flop_threshold() {
        seed_kernel(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, k, n);
        return;
    }
    let p = pool::current();
    match kind {
        KernelKind::Packed => packed_parallel(a.as_slice(), b.as_slice(), c, m, k, n, &p),
        KernelKind::Banded => banded_parallel(a.as_slice(), b.as_slice(), c, m, k, n, &p),
        KernelKind::Serial => unreachable!("serial handled above"),
    }
}

/// `C = A·B` forced through the v1 serial kernel (bench baseline, and
/// what `FFF_GEMM_KERNEL=serial` routes everything to).
pub fn gemm_scalar(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(k, b.rows(), "gemm_scalar: inner dims");
    let mut c = Matrix::zeros(m, n);
    seed_kernel(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, k, n);
    c
}

/// `C = A·B` forced through the packed microkernel path on the current
/// pool, regardless of size (property tests and bench suite).
pub fn gemm_packed(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(k, b.rows(), "gemm_packed: inner dims");
    let mut c = Matrix::zeros(m, n);
    let p = pool::current();
    packed_parallel(a.as_slice(), b.as_slice(), &mut c, m, k, n, &p);
    c
}

/// Rows per parallel band: aim for ~4 tasks per thread (work stealing
/// evens out the tail), within [MR, 64], rounded up to a whole number of
/// MR-row micro-panels. Band boundaries do not affect numerics.
fn band_rows(m: usize, threads: usize) -> usize {
    let target = m.div_ceil(threads.max(1) * 4).clamp(MR, 64);
    target.div_ceil(MR) * MR
}

// ---------------------------------------------------------------------------
// Banded path: the v1 i-k-j kernel over pool-dispatched row bands.
// ---------------------------------------------------------------------------

/// The v1 serial kernel: `C += A·B` over raw row-major slices. Per element
/// the accumulation order is `p` ascending within each k-block — identical
/// whether invoked on a full matrix or any row band of it.
fn seed_kernel(av: &[f32], bv: &[f32], cv: &mut [f32], m: usize, k: usize, n: usize) {
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        let mut i = 0;
        // 4-row unrolled macro-kernel.
        while i + 4 <= m {
            let (a0, a1, a2, a3) = (
                &av[i * k..(i + 1) * k],
                &av[(i + 1) * k..(i + 2) * k],
                &av[(i + 2) * k..(i + 3) * k],
                &av[(i + 3) * k..(i + 4) * k],
            );
            for p in k0..k1 {
                let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
                let brow = &bv[p * n..p * n + n];
                let (c01, rest) = cv[i * n..].split_at_mut(2 * n);
                let (c0, c1) = c01.split_at_mut(n);
                let (c2, c3rest) = rest.split_at_mut(n);
                let c3 = &mut c3rest[..n];
                for (j, &bj) in brow.iter().enumerate() {
                    c0[j] += x0 * bj;
                    c1[j] += x1 * bj;
                    c2[j] += x2 * bj;
                    c3[j] += x3 * bj;
                }
            }
            i += 4;
        }
        // Remainder rows.
        while i < m {
            let arow = &av[i * k..(i + 1) * k];
            let crow = &mut cv[i * n..(i + 1) * n];
            for p in k0..k1 {
                let x = arow[p];
                let brow = &bv[p * n..p * n + n];
                for (cj, &bj) in crow.iter_mut().zip(brow) {
                    *cj += x * bj;
                }
            }
            i += 1;
        }
    }
}

/// Row-band parallel wrapper around [`seed_kernel`].
fn banded_parallel(
    av: &[f32],
    bv: &[f32],
    c: &mut Matrix,
    m: usize,
    k: usize,
    n: usize,
    p: &pool::ThreadPool,
) {
    banded_parallel_epi(av, bv, c, m, k, n, p, Epilogue::None)
}

/// [`banded_parallel`] with the epilogue applied per band right after
/// its accumulation (while the band is cache-hot); same per-element ops
/// and order as a whole-matrix [`epilogue_pass`].
#[allow(clippy::too_many_arguments)]
fn banded_parallel_epi(
    av: &[f32],
    bv: &[f32],
    c: &mut Matrix,
    m: usize,
    k: usize,
    n: usize,
    p: &pool::ThreadPool,
    epi: Epilogue,
) {
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    p.run(n_bands, &|t| {
        let i0 = t * band;
        let rows = band.min(m - i0);
        // SAFETY: bands are disjoint row ranges of `c`, and `run` returns
        // before `c` is touched again by the caller.
        let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        seed_kernel(&av[i0 * k..(i0 + rows) * k], bv, cv, rows, k, n);
        epilogue_pass(cv, rows, n, epi);
    });
}

// ---------------------------------------------------------------------------
// Packed path: cache-blocked panels + the dispatched 4x8 microkernel.
// ---------------------------------------------------------------------------

/// Pack a `kc`-deep panel of `B` (rows `k0..k0+kc`, all `n` columns) into
/// NR-wide micro-panels: `bpack[jp][p][c]`, zero-padded in the tail panel.
fn pack_b(bv: &[f32], n: usize, k0: usize, kc: usize, bpack: &mut [f32]) {
    let n_panels = n.div_ceil(NR);
    for jp in 0..n_panels {
        let j0 = jp * NR;
        let nr = NR.min(n - j0);
        let dst = &mut bpack[jp * kc * NR..(jp + 1) * kc * NR];
        for p in 0..kc {
            let src = &bv[(k0 + p) * n + j0..(k0 + p) * n + j0 + nr];
            let d = &mut dst[p * NR..p * NR + NR];
            d[..nr].copy_from_slice(src);
            d[nr..].fill(0.0);
        }
    }
}

/// Pack `rows` rows of `A` starting at `i0`, columns `k0..k0+kc`, into
/// MR-tall micro-panels: `apack[ip][p][r]`, zero-padded in the tail panel.
fn pack_a(av: &[f32], k: usize, i0: usize, rows: usize, k0: usize, kc: usize, apack: &mut [f32]) {
    let m_panels = rows.div_ceil(MR);
    for ip in 0..m_panels {
        let r0 = ip * MR;
        let mr = MR.min(rows - r0);
        let dst = &mut apack[ip * kc * MR..(ip + 1) * kc * MR];
        for p in 0..kc {
            let d = &mut dst[p * MR..p * MR + MR];
            for (r, dr) in d[..mr].iter_mut().enumerate() {
                *dr = av[(i0 + r0 + r) * k + k0 + p];
            }
            d[mr..].fill(0.0);
        }
    }
}

/// Packed serial band: pack the band's rows of `A` (into this thread's
/// [`scratch`] buffer — no allocation once warm), then run the epilogue
/// microkernel from [`kernels::table`] over every (MR row-panel ×
/// NR col-panel) tile. `epi` fires in the tiles' store phase; the caller
/// passes [`Epilogue::None`] for every k-panel but the last.
#[allow(clippy::too_many_arguments)]
fn packed_band(
    av: &[f32],
    bpack: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    k0: usize,
    kc: usize,
    micro: kernels::Micro4x8Epi,
    epi: Epilogue,
) {
    let m_panels = rows.div_ceil(MR);
    let n_panels = n.div_ceil(NR);
    scratch::with_f32(m_panels * MR * kc, |apack| {
        pack_a(av, k, i0, rows, k0, kc, apack);
        for ip in 0..m_panels {
            let r0 = ip * MR;
            let mr = MR.min(rows - r0);
            let ap = &apack[ip * kc * MR..(ip + 1) * kc * MR];
            for jp in 0..n_panels {
                let j0 = jp * NR;
                let nr = NR.min(n - j0);
                let bp = &bpack[jp * kc * NR..(jp + 1) * kc * NR];
                micro(kc, ap, bp, &mut cv[r0 * n + j0..], n, mr, nr, epi.narrow(j0));
            }
        }
    });
}

/// Packed + pooled `C += A·B`: per k-panel, `B` is packed once (shared,
/// read-only) and row bands are dispatched as pool tasks, each packing its
/// own slice of `A` into a thread-local scratch buffer.
fn packed_parallel(
    av: &[f32],
    bv: &[f32],
    c: &mut Matrix,
    m: usize,
    k: usize,
    n: usize,
    p: &pool::ThreadPool,
) {
    packed_parallel_epi(av, bv, c, m, k, n, p, Epilogue::None)
}

/// [`packed_parallel`] with `epi` fused into the stores of the **last**
/// k-panel (earlier panels store with [`Epilogue::None`], i.e. plain
/// accumulation), so each element passes through the epilogue exactly
/// once, after its full sum — the order [`epilogue_pass`] replicates.
#[allow(clippy::too_many_arguments)]
fn packed_parallel_epi(
    av: &[f32],
    bv: &[f32],
    c: &mut Matrix,
    m: usize,
    k: usize,
    n: usize,
    p: &pool::ThreadPool,
    epi: Epilogue,
) {
    let micro = kernels::table().micro_4x8_epi;
    let n_panels = n.div_ceil(NR);
    let kc_max = k.min(KC);
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    scratch::with_f32(n_panels * kc_max * NR, |bpack| {
        for k0 in (0..k).step_by(KC) {
            let kc = KC.min(k - k0);
            pack_b(bv, n, k0, kc, bpack);
            let bp: &[f32] = &bpack[..n_panels * kc * NR];
            let panel_epi = if k0 + kc == k { epi } else { Epilogue::None };
            p.run(n_bands, &|t| {
                let i0 = t * band;
                let rows = band.min(m - i0);
                // SAFETY: bands are disjoint row ranges of `c`, and `run`
                // returns before `c` is touched again by the caller.
                let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
                packed_band(av, bp, cv, i0, rows, k, n, k0, kc, micro, panel_epi);
            });
        }
    });
}

// ---------------------------------------------------------------------------
// Prepacked-B path: serving-time bucket GEMMs over weights packed once.
// ---------------------------------------------------------------------------

/// A weight matrix prepacked into the packed path's NR-wide micro-panels,
/// built **once** (model-compile time) from the transposed `n×k` layout
/// the FFF leaf storage uses. Serving-time bucket GEMMs then skip
/// `pack_b` entirely and feed the microkernel directly; only the gathered
/// `A` rows are packed per call — straight from scattered batch rows, so
/// the old gather-copy disappears too.
///
/// Layout: ascending k-chunks of `kc = min(KC, k − k0)` packed rows, each
/// chunk holding `ceil(n/NR)` panels of `kc × NR` (columns ≥ `n`
/// zero-padded), chunks concatenated. Identical panel contents to what
/// `pack_b` produces from the untransposed matrix, so a product through
/// [`gemm_packed_gather_epi`] is bit-identical to the packed-kind
/// [`gemm_bias`] over the same operands.
#[derive(Clone, Debug)]
pub struct PackedB {
    k: usize,
    n: usize,
    data: Vec<f32>,
}

impl PackedB {
    /// Pack from the transposed (`n × k`) layout.
    pub fn pack_nt(bt: &Matrix) -> PackedB {
        let (n, k) = bt.shape();
        let n_panels = n.div_ceil(NR);
        let mut data = vec![0.0f32; n_panels * NR * k];
        let mut off = 0;
        for k0 in (0..k).step_by(KC) {
            let kc = KC.min(k - k0);
            for jp in 0..n_panels {
                let j0 = jp * NR;
                let nc = NR.min(n - j0);
                for p in 0..kc {
                    let dst = &mut data[off + (jp * kc + p) * NR..][..NR];
                    for (c, d) in dst.iter_mut().enumerate().take(nc) {
                        *d = bt.get(j0 + c, k0 + p);
                    }
                    // Columns ≥ n stay at the zero fill.
                }
            }
            off += n_panels * kc * NR;
        }
        PackedB { k, n, data }
    }

    /// Inner dimension (rows of the packed operand).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The panel block of the k-chunk starting at byte offset `off`.
    fn chunk(&self, off: usize, kc: usize) -> &[f32] {
        &self.data[off..off + self.n.div_ceil(NR) * kc * NR]
    }
}

/// `C = epi(Xrows · B)` through the packed microkernel over a prepacked
/// `B`: left-operand row `i` is `x.row(rows[i])`, packed straight into
/// MR-tall panels (gather fused into the pack); `C` is the caller's
/// `rows.len() × n` row-major scratch, zeroed here; `epi` fuses into the
/// last k-chunk's stores. Single-threaded by design (the leaf-bucket
/// callers are pool tasks); the A-panel buffer comes from [`scratch`],
/// so steady state allocates nothing.
pub fn gemm_packed_gather_epi(
    x: &Matrix,
    rows: &[usize],
    b: &PackedB,
    c: &mut [f32],
    epi: Epilogue,
) {
    let m = rows.len();
    let k = x.cols();
    let n = b.n;
    assert_eq!(k, b.k, "gemm_packed_gather: inner dims");
    assert!(c.len() >= m * n, "gemm_packed_gather: short output");
    if let Epilogue::Bias(bb) | Epilogue::BiasRelu(bb) = epi {
        assert!(bb.len() >= n, "gemm_packed_gather: short bias");
    }
    let c = &mut c[..m * n];
    c.fill(0.0);
    if k == 0 {
        epilogue_pass(c, m, n, epi);
        return;
    }
    let micro = kernels::table().micro_4x8_epi;
    let m_panels = m.div_ceil(MR);
    let n_panels = n.div_ceil(NR);
    let kc_max = k.min(KC);
    scratch::with_f32(m_panels * MR * kc_max, |apack| {
        let mut off = 0;
        for k0 in (0..k).step_by(KC) {
            let kc = KC.min(k - k0);
            pack_a_gather(x, rows, k0, kc, apack);
            let bp = b.chunk(off, kc);
            let chunk_epi = if k0 + kc == k { epi } else { Epilogue::None };
            for ip in 0..m_panels {
                let r0 = ip * MR;
                let mr = MR.min(m - r0);
                let ap = &apack[ip * kc * MR..(ip + 1) * kc * MR];
                for jp in 0..n_panels {
                    let j0 = jp * NR;
                    let nr = NR.min(n - j0);
                    let bpp = &bp[jp * kc * NR..(jp + 1) * kc * NR];
                    micro(kc, ap, bpp, &mut c[r0 * n + j0..], n, mr, nr, chunk_epi.narrow(j0));
                }
            }
            off += n_panels * kc * NR;
        }
    });
}

/// Pack gathered rows `x.row(rows[i])` (columns `k0..k0+kc`) into MR-tall
/// micro-panels — same panel contents `pack_a` would produce from a
/// contiguous copy of those rows, without materializing the copy.
fn pack_a_gather(x: &Matrix, rows: &[usize], k0: usize, kc: usize, apack: &mut [f32]) {
    let m = rows.len();
    let m_panels = m.div_ceil(MR);
    for ip in 0..m_panels {
        let r0 = ip * MR;
        let mr = MR.min(m - r0);
        let dst = &mut apack[ip * kc * MR..(ip + 1) * kc * MR];
        if mr < MR {
            dst.fill(0.0); // zero-pad the tail panel's missing rows
        }
        for r in 0..mr {
            let src = &x.row(rows[r0 + r])[k0..k0 + kc];
            for (p, &v) in src.iter().enumerate() {
                dst[p * MR + r] = v;
            }
        }
    }
}

/// Scatter-row output GEMM — the leaf bucket's second product, writing
/// each result row **directly into its final row of the output matrix**
/// (deleting the contiguous staging buffer and the copy-back loop):
/// `y[rows[i]] = bias + Σ_p a[i·k+p] · b_row(p)`, with exact-zero `a`
/// terms skipped (post-ReLU activations are roughly half zeros, halving
/// the axpy traffic). Per-element accumulation order is `p` ascending —
/// the serial i-k-j kernel's order — independent of bucket split and
/// thread count. The zero skip can flip the sign of an exactly-zero
/// output relative to a non-skipping kernel (`-0.0 + +0.0 = +0.0`);
/// finite nonzero results are unaffected.
///
/// # Safety
/// `y` must point to a row-major buffer with row stride `n` large enough
/// that every `rows[i]` row is in bounds, the buffer must outlive the
/// call, and no other thread may touch the rows named by `rows` while it
/// runs (the leaf-bucket dispatch hands each task a disjoint row set).
#[allow(clippy::too_many_arguments)]
pub(crate) unsafe fn gemm_bias_scatter_raw(
    av: &[f32],
    k: usize,
    bv: &[f32],
    n: usize,
    bias: &[f32],
    rows: &[usize],
    y: *mut f32,
) {
    debug_assert!(av.len() >= rows.len() * k, "gemm_bias_scatter: short A");
    debug_assert!(bv.len() >= k * n, "gemm_bias_scatter: short B");
    debug_assert_eq!(bias.len(), n, "gemm_bias_scatter: bias length");
    for (i, &r) in rows.iter().enumerate() {
        // SAFETY: row `r` is in bounds of the `y` buffer and exclusively
        // ours while this runs, per this function's `# Safety` contract.
        let dst = unsafe { std::slice::from_raw_parts_mut(y.add(r * n), n) };
        dst.copy_from_slice(bias);
        for (p, &xv) in av[i * k..(i + 1) * k].iter().enumerate() {
            if xv != 0.0 {
                axpy_slice(xv, &bv[p * n..(p + 1) * n], dst);
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Quantized (int8) serving path: per-panel-scaled weights, i32 tiles,
// dequantizing epilogue store.
// ---------------------------------------------------------------------------

/// A weight matrix quantized to int8 with symmetric per-panel scales and
/// packed for the int8 microkernels — the serving-time representation
/// behind `FFF_PRECISION=int8` / `Precision::Int8`. Built **once** at
/// model-compile time (and only when the int8 mode is active — an f32
/// process never pays the extra bytes, the same rule [`PackedB`]
/// follows); a bucket GEMM then streams a quarter of the f32 panel
/// traffic, which is the whole win at FFF serving shapes (leaf GEMMs are
/// weight-bandwidth-bound — EXPERIMENTS.md §Perf iteration 6).
///
/// Quantization: each NR-column panel gets one symmetric f32 scale,
/// `absmax/127` over its `k × NR` block (an all-zero panel pins scale
/// `1.0` with all-zero bytes — the divide-by-zero guard); elements store
/// as `round(v/scale)` clamped to ±127, so −128 never appears and the
/// AVX2 `vpmaddubsw` kernel cannot saturate. B-side bytes stay plain
/// signed i8; only A-side activation bytes are biased
/// (see [`kernels::quantize_row_q8_scalar`]).
///
/// Layout: `ceil(n/NR)` panels, each `kg = ceil(k/QK)` groups of
/// `NR` columns × `QK` consecutive k-bytes (32 bytes — one ymm row, one
/// column's group per 32-bit lane), `k` zero-padded up to `kg*QK`.
/// Unlike [`PackedB`] there is no KC chunking: the int8 panel is 4x
/// denser, so even a `k = 1024` panel sits comfortably in L1 next to the
/// A-panel bytes.
///
/// Alongside the bytes, each panel carries a per-column correction row
/// `corr[c] = 127·Σ_p byte[c][p]` (pad bytes are zero and add nothing).
/// The VNNI kernel feeds `vpdpbusd` the **biased** A bytes directly and
/// subtracts `corr` once after the `k` loop — `Σ(q+127)·b − 127·Σb =
/// Σq·b`, exact in i32 — which is what makes the biased-A trick free at
/// serving time: the correction is precomputed here, at compile time.
#[derive(Clone, Debug)]
pub struct QuantPackedB {
    k: usize,
    n: usize,
    /// `k.div_ceil(QK)` zero-padded k-groups per column.
    kg: usize,
    /// `[ceil(n/NR) panels][kg groups][NR columns][QK k-bytes]`.
    data: Vec<i8>,
    /// One symmetric scale per NR-column panel.
    scales: Vec<f32>,
    /// `[ceil(n/NR) panels][NR columns]` of `127·Σ_p byte[c][p]` — the
    /// biased-A correction the VNNI kernel subtracts.
    corr: Vec<i32>,
}

impl QuantPackedB {
    /// Quantize + pack from the transposed (`n × k`) layout the FFF leaf
    /// storage uses (same orientation as [`PackedB::pack_nt`]).
    pub fn quantize_nt(bt: &Matrix) -> QuantPackedB {
        let (n, k) = bt.shape();
        let kg = k.div_ceil(QK);
        let n_panels = n.div_ceil(NR);
        let mut data = vec![0i8; n_panels * kg * NR * QK];
        let mut scales = Vec::with_capacity(n_panels);
        let mut corr = vec![0i32; n_panels * NR];
        for jp in 0..n_panels {
            let j0 = jp * NR;
            let nc = NR.min(n - j0);
            let mut absmax = 0.0f32;
            for c in 0..nc {
                for &v in bt.row(j0 + c) {
                    absmax = absmax.max(v.abs());
                }
            }
            let scale = if absmax > 0.0 { absmax / 127.0 } else { 1.0 };
            // Same rounding statement as the A-side quantizer
            // (`kernels::quantize_row_q8_scalar`, minus the bias:
            // reciprocal multiply, float-domain clamp, copysign
            // round-half-away-from-zero) so A- and B-side bytes follow
            // one spec.
            let inv = 1.0 / scale;
            let panel = &mut data[jp * kg * NR * QK..(jp + 1) * kg * NR * QK];
            for c in 0..nc {
                for (p, &v) in bt.row(j0 + c).iter().enumerate() {
                    let t = (v * inv).clamp(-127.0, 127.0);
                    panel[(p / QK) * NR * QK + c * QK + (p % QK)] =
                        (t + 0.5f32.copysign(t)) as i8;
                }
            }
            // The biased-A correction row, summed over the packed bytes
            // themselves (zero pads included — they add nothing), so it
            // is consistent with the panel by construction.
            for (c, slot) in corr[jp * NR..(jp + 1) * NR].iter_mut().enumerate() {
                let mut sum = 0i32;
                for g in 0..kg {
                    for q in 0..QK {
                        sum += panel[g * NR * QK + c * QK + q] as i32;
                    }
                }
                *slot = 127 * sum;
            }
            scales.push(scale);
        }
        QuantPackedB { k, n, kg, data, scales, corr }
    }

    /// Inner dimension (rows of the packed operand).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output columns.
    pub fn n(&self) -> usize {
        self.n
    }

    /// The symmetric scale of column panel `jp` (columns `jp*NR..`).
    pub fn scale(&self, jp: usize) -> f32 {
        self.scales[jp]
    }

    /// The quantized byte of (column `j`, inner index `p`) — the scalar
    /// accessor the per-sample int8 fallback and the golden/property
    /// tests read the packed layout through. Pad positions (`p ≥ k` never
    /// stored) hold zero.
    pub fn get_q(&self, j: usize, p: usize) -> i8 {
        let jp = j / NR;
        let c = j % NR;
        self.data[jp * self.kg * NR * QK + (p / QK) * NR * QK + c * QK + (p % QK)]
    }

    /// The biased-A correction of (column `j`, i.e. `127·Σ_p byte[j][p]`)
    /// — the scalar accessor the property tests pin the table through.
    pub fn corr_of(&self, j: usize) -> i32 {
        self.corr[(j / NR) * NR + (j % NR)]
    }

    /// Quantized payload size in bytes (diagnostics: the f32 panel is
    /// ~4x this).
    pub fn bytes(&self) -> usize {
        self.data.len()
            + self.scales.len() * std::mem::size_of::<f32>()
            + self.corr.len() * std::mem::size_of::<i32>()
    }

    /// The packed byte panel of columns `jp*NR..`.
    fn panel(&self, jp: usize) -> &[i8] {
        &self.data[jp * self.kg * NR * QK..(jp + 1) * self.kg * NR * QK]
    }

    /// The correction row of panel `jp` (NR i32 values).
    fn corr_panel(&self, jp: usize) -> &[i32] {
        &self.corr[jp * NR..(jp + 1) * NR]
    }
}

/// Quantize gathered rows `x.row(rows[i])` contiguously into biased-u8
/// A rows — `astride = kg·QK` bytes per row, the layout the fused tiles
/// broadcast from ([`kernels::TileI8`]). Each row's ragged `k` tail is
/// filled with the biased zero [`kernels::QA_ZERO`] (unbiased 0, and the
/// matching B pad bytes are 0 — either way the pads contribute nothing),
/// and each row's symmetric scale lands in `sa[i]`. Pad rows `m..` of
/// `qa` are biased-zero-filled too: the tiles read them (the store is
/// `mr`-guarded, the reads are not), so the fill only keeps scratch
/// reuse deterministic — its value never reaches an output.
fn quantize_gather_rows(
    x: &Matrix,
    rows: &[usize],
    ks: &kernels::I8Kernels,
    qa: &mut [u8],
    sa: &mut [f32],
) {
    let k = x.cols();
    let astride = k.div_ceil(QK) * QK;
    for (r, &row) in rows.iter().enumerate() {
        let dst = &mut qa[r * astride..(r + 1) * astride];
        if k % QK != 0 {
            dst[k..].fill(kernels::QA_ZERO);
        }
        sa[r] = (ks.quant_row)(x.row(row), dst);
    }
    let used = rows.len() * astride;
    if used < qa.len() {
        qa[used..].fill(kernels::QA_ZERO);
    }
}

/// Contiguous-A twin of [`quantize_gather_rows`] for the bucket's second
/// GEMM (the post-ReLU `a1` activations are already a dense `m × k`
/// scratch block).
fn quantize_contig_rows(
    av: &[f32],
    k: usize,
    m: usize,
    ks: &kernels::I8Kernels,
    qa: &mut [u8],
    sa: &mut [f32],
) {
    let astride = k.div_ceil(QK) * QK;
    for r in 0..m {
        let dst = &mut qa[r * astride..(r + 1) * astride];
        if k % QK != 0 {
            dst[k..].fill(kernels::QA_ZERO);
        }
        sa[r] = (ks.quant_row)(&av[r * k..(r + 1) * k], dst);
    }
    let used = m * astride;
    if used < qa.len() {
        qa[used..].fill(kernels::QA_ZERO);
    }
}

/// The shared int8 GEMM core over pre-quantized A rows: fused tiles
/// (kernel + dequant/bias/ReLU store in one pass, i32 accumulators held
/// in registers), two-panel pairing where the kernel set has an x2 tile
/// (shares each A broadcast across 16 output columns), the scalar
/// narrow tile for the ragged column tail (bit-identical: exact i32 +
/// the same store statement), and per-row output offsets so one core
/// serves contiguous output (`rows_out = None` → row `i` at `c + i*n`)
/// and scatter-row output (`rows_out = Some(rows)` → row `i` at
/// `c + rows[i]*n`). `Epilogue::None` runs the tiles against a zero
/// bias array — the int8 store contract is *overwrite with bias add*,
/// so "no epilogue" is defined as `bias ≡ 0.0`, `relu` off.
///
/// # Safety
/// `c` must point to a row-major f32 buffer with row stride `n = b.n()`
/// such that every output row named by `rows_out` (or `0..m` when
/// contiguous) is in bounds, outlives the call, and is touched by no
/// other thread; `qa` must hold `ceil(m/MR)·MR` rows of `b.kg·QK`
/// biased bytes and `sa` the `m` row scales (as the quantize fronts
/// produce).
unsafe fn gemm_quant_core(
    qa: &[u8],
    sa: &[f32],
    m: usize,
    b: &QuantPackedB,
    epi: Epilogue,
    ks: &kernels::I8Kernels,
    c: *mut f32,
    rows_out: Option<&[usize]>,
) {
    // SAFETY: caller contract (`# Safety` above): `qa`/`sa` cover the padded
    // `m` rows (debug-asserted), each `roff` slot points at an in-bounds
    // output row that no other thread touches, and the tiles' own
    // contracts are met by the packed shapes `b` carries.
    unsafe {
        static ZB: [f32; 2 * NR] = [0.0; 2 * NR];
        if m == 0 {
            return;
        }
        let n = b.n;
        let kg = b.kg;
        let astride = kg * QK;
        let n_panels = b.scales.len();
        let relu = matches!(epi, Epilogue::BiasRelu(_));
        let bias_base: *const f32 = match epi {
            Epilogue::None => ZB.as_ptr(),
            Epilogue::Bias(bb) | Epilogue::BiasRelu(bb) => bb.as_ptr(),
        };
        let zero_bias = matches!(epi, Epilogue::None);
        debug_assert!(qa.len() >= m.div_ceil(MR) * MR * astride, "gemm_quant_core: short qa");
        debug_assert!(sa.len() >= m, "gemm_quant_core: short sa");
        let mp = m.div_ceil(MR);
        for ip in 0..mp {
            let r0 = ip * MR;
            let mr = MR.min(m - r0);
            let ap = qa.as_ptr().add(r0 * astride);
            let sp = sa.as_ptr().add(r0);
            // Per-row output offsets; pad slots clamp to the last real row
            // (the tiles never store them, but SIMD stores are emitted for
            // all MR slots before the `mr` guard prunes — the clamped
            // offset keeps the dead slots pointing at valid memory).
            let mut roff = [0usize; MR];
            for (r, slot) in roff.iter_mut().enumerate() {
                let rr = (r0 + r).min(m - 1);
                *slot = match rows_out {
                    Some(ro) => ro[rr] * n,
                    None => rr * n,
                };
            }
            let mut jp = 0usize;
            if let Some(tx2) = ks.tile_x2 {
                while jp + 2 <= n_panels && n - jp * NR >= 2 * NR {
                    let j0 = jp * NR;
                    let bj = if zero_bias { ZB.as_ptr() } else { bias_base.add(j0) };
                    tx2(
                        kg,
                        ap,
                        astride,
                        b.panel(jp).as_ptr(),
                        b.panel(jp + 1).as_ptr(),
                        b.corr_panel(jp).as_ptr(),
                        b.corr_panel(jp + 1).as_ptr(),
                        sp,
                        b.scales[jp],
                        b.scales[jp + 1],
                        bj,
                        relu,
                        c.add(j0),
                        roff.as_ptr(),
                        mr,
                    );
                    jp += 2;
                }
            }
            while jp < n_panels {
                let j0 = jp * NR;
                let nr = NR.min(n - j0);
                let bj = if zero_bias { ZB.as_ptr() } else { bias_base.add(j0) };
                if nr == NR {
                    (ks.tile)(
                        kg,
                        ap,
                        astride,
                        b.panel(jp).as_ptr(),
                        b.corr_panel(jp).as_ptr(),
                        sp,
                        b.scales[jp],
                        bj,
                        relu,
                        c.add(j0),
                        roff.as_ptr(),
                        mr,
                    );
                } else {
                    kernels::tile_i8_scalar(
                        kg,
                        ap,
                        astride,
                        b.panel(jp).as_ptr(),
                        b.corr_panel(jp).as_ptr(),
                        sp,
                        b.scales[jp],
                        bj,
                        relu,
                        c.add(j0),
                        roff.as_ptr(),
                        mr,
                        nr,
                    );
                }
                jp += 1;
            }
        }
    }
}

/// `C = epi(quant(Xrows) · Bq)` — the int8 twin of
/// [`gemm_packed_gather_epi`]: left-operand row `i` is `x.row(rows[i])`,
/// quantized on the fly to biased-u8 (per-row absmax scale) into
/// contiguous A rows, then the fused-tile core stores each dequantized
/// element `(acc as f32)·(sa·sb) + bias[j]` (+ReLU) in the same pass —
/// an overwrite, so `c` needs no zeroing. Single-threaded by design
/// (the leaf-bucket callers are pool tasks); A bytes and row scales
/// come from [`scratch`], so steady state allocates nothing.
///
/// Results are bit-identical across thread counts, bucket splits, and
/// forced kernel kinds: the quantized bytes per row depend only on that
/// row (and every quantizer matches the scalar statement), i32
/// accumulation is exact, and the dequant store is one fixed scalar
/// statement. `k == 0` degenerates naturally: `kg = 0` tiles store
/// `epi(0.0)` per element.
pub fn gemm_quant_gather_epi(
    x: &Matrix,
    rows: &[usize],
    b: &QuantPackedB,
    c: &mut [f32],
    epi: Epilogue,
) {
    let m = rows.len();
    let k = x.cols();
    let n = b.n;
    assert_eq!(k, b.k, "gemm_quant_gather: inner dims");
    assert!(c.len() >= m * n, "gemm_quant_gather: short output");
    if let Epilogue::Bias(bb) | Epilogue::BiasRelu(bb) = epi {
        assert!(bb.len() >= n, "gemm_quant_gather: short bias");
    }
    if m == 0 {
        return;
    }
    let ks = kernels::active_i8();
    let astride = b.kg * QK;
    let mp = m.div_ceil(MR);
    scratch::with_u8(mp * MR * astride, |qa| {
        scratch::with_f32(m, |sa| {
            quantize_gather_rows(x, rows, ks, qa, sa);
            // SAFETY: `c` covers m rows of n (asserted), qa/sa filled
            // above with the contracted shapes.
            unsafe { gemm_quant_core(qa, sa, m, b, epi, ks, c.as_mut_ptr(), None) }
        });
    });
}

/// Scatter-row int8 output GEMM — the quantized twin of
/// [`gemm_bias_scatter_raw`]: quantizes the post-ReLU `a1` block per
/// row, then the fused-tile core writes each dequantized `bias`-epilogue
/// row **directly into its final row of the output matrix**:
/// `y[rows[i]][j] = (acc_ij as f32)·(sa_i·sb_jp) + bias[j]`. Every named
/// row is fully overwritten (each output column belongs to exactly one
/// panel tile); other rows are never touched. Scattered and contiguous
/// int8 results carry identical bits — same quantize statement, same
/// core, only the per-row output offsets differ.
///
/// # Safety
/// Same contract as [`gemm_bias_scatter_raw`]: `y` must point to a
/// row-major buffer with row stride `n` large enough that every
/// `rows[i]` row is in bounds, the buffer must outlive the call, and no
/// other thread may touch the rows named by `rows` while it runs.
pub(crate) unsafe fn gemm_quant_scatter_raw(
    av: &[f32],
    k: usize,
    b: &QuantPackedB,
    n: usize,
    bias: &[f32],
    rows: &[usize],
    y: *mut f32,
) {
    debug_assert!(av.len() >= rows.len() * k, "gemm_quant_scatter: short A");
    assert_eq!(k, b.k, "gemm_quant_scatter: inner dims");
    assert_eq!(n, b.n, "gemm_quant_scatter: output width");
    debug_assert_eq!(bias.len(), n, "gemm_quant_scatter: bias length");
    let m = rows.len();
    if m == 0 {
        return;
    }
    let ks = kernels::active_i8();
    let astride = b.kg * QK;
    let mp = m.div_ceil(MR);
    scratch::with_u8(mp * MR * astride, |qa| {
        scratch::with_f32(m, |sa| {
            quantize_contig_rows(av, k, m, ks, qa, sa);
            // SAFETY: output rows are in bounds and exclusively ours per
            // this function's contract; qa/sa filled just above.
            unsafe { gemm_quant_core(qa, sa, m, b, Epilogue::Bias(bias), ks, y, Some(rows)) }
        });
    });
}

/// L2 over pre-quantized hidden rows — the second sweep of the fused
/// leaf path: the shared core with scatter-row output and `Bias`
/// epilogue, i.e. [`gemm_quant_scatter_raw`] minus the quantize front
/// (sweep 1's [`leaf_quant_l1`] already produced `qa1`/`sa1`). The two
/// entry points are bit-identical because the fused leaf tile's
/// requantize epilogue replicates the row quantizer statement.
///
/// # Safety
/// Same output contract as [`gemm_quant_scatter_raw`]; `qa1` must hold
/// `ceil(rows.len()/MR)·MR` rows of `b.kg()·QK` biased bytes and `sa1`
/// `rows.len()` scales, as [`leaf_quant_l1`] produces.
pub(crate) unsafe fn gemm_quant_scatter_prequant(
    qa1: &[u8],
    sa1: &[f32],
    b: &QuantPackedB,
    bias: &[f32],
    rows: &[usize],
    y: *mut f32,
) {
    debug_assert_eq!(bias.len(), b.n, "gemm_quant_scatter_prequant: bias length");
    if rows.is_empty() {
        return;
    }
    // SAFETY: the output-row and qa1/sa1 shape obligations are exactly
    // this function's `# Safety` contract, forwarded to the core.
    unsafe {
        gemm_quant_core(
            qa1,
            sa1,
            rows.len(),
            b,
            Epilogue::Bias(bias),
            kernels::active_i8(),
            y,
            Some(rows),
        );
    }
}

/// Whether the register-fused leaf path can serve leaf width `ell`:
/// `ell == 2·NR` (one L1 output row is exactly two SIMD registers, the
/// shape the leaf tile requantizes in-register) and the active int8
/// kernel set has a leaf tile (the SIMD `packed` kind; the scalar set
/// takes the unfused store-then-requantize route instead).
pub(crate) fn fused_leaf_available(ell: usize) -> bool {
    ell == 2 * NR && kernels::active_i8().tile_leaf.is_some()
}

/// Fused leaf L1 over gathered rows: quantize `rows` of `x`, then run
/// the register-fused leaf tile — L1 GEMM, bias, ReLU, and requantize
/// of the hidden row, all without leaving registers — writing the
/// quantized hidden rows straight into `qa1` (`q1.n()` bytes per row)
/// and their scales into `sa1`. Pad rows `rows.len()..ceil(m/MR)·MR`
/// of `qa1` are biased-zero-filled, matching the quantize fronts.
///
/// Bit-identical to `gemm_quant_gather_epi(BiasRelu)` followed by
/// per-row [`kernels::quantize_row_q8_scalar`]: the epilogue replicates
/// the dequant store and row-quantizer statements and skips only a
/// lossless f32 store/load round trip. Caller must have checked
/// [`fused_leaf_available`] (`q1.n() == 2·NR`, leaf tile present).
pub(crate) fn leaf_quant_l1(
    x: &Matrix,
    rows: &[usize],
    q1: &QuantPackedB,
    b1: &[f32],
    qa1: &mut [u8],
    sa1: &mut [f32],
) {
    let m = rows.len();
    let k = x.cols();
    let ell = q1.n;
    assert_eq!(k, q1.k, "leaf_quant_l1: inner dims");
    assert_eq!(ell, 2 * NR, "leaf_quant_l1: leaf width");
    assert!(b1.len() >= ell, "leaf_quant_l1: short bias");
    if m == 0 {
        return;
    }
    let ks = kernels::active_i8();
    let tleaf = ks
        .tile_leaf
        .expect("leaf_quant_l1: active kernel set has no leaf tile");
    let kg = q1.kg;
    let astride = kg * QK;
    let mp = m.div_ceil(MR);
    assert!(qa1.len() >= mp * MR * ell, "leaf_quant_l1: short qa1");
    assert!(sa1.len() >= m, "leaf_quant_l1: short sa1");
    scratch::with_u8(mp * MR * astride, |qa| {
        scratch::with_f32(m, |sa| {
            quantize_gather_rows(x, rows, ks, qa, sa);
            for ip in 0..mp {
                let r0 = ip * MR;
                let mr = MR.min(m - r0);
                // SAFETY: `qa` holds `mp·MR` rows of `astride` bytes;
                // `q1` has exactly two panels (`ell == 2·NR` asserted);
                // `qa1`/`sa1` bounds asserted above and each tile's
                // output rows are disjoint.
                unsafe {
                    tleaf(
                        kg,
                        qa.as_ptr().add(r0 * astride),
                        astride,
                        q1.panel(0).as_ptr(),
                        q1.panel(1).as_ptr(),
                        q1.corr_panel(0).as_ptr(),
                        q1.corr_panel(1).as_ptr(),
                        sa.as_ptr().add(r0),
                        q1.scales[0],
                        q1.scales[1],
                        b1.as_ptr(),
                        qa1.as_mut_ptr().add(r0 * ell),
                        ell,
                        sa1.as_mut_ptr().add(r0),
                        mr,
                    );
                }
            }
        });
    });
    if m * ell < mp * MR * ell {
        qa1[m * ell..mp * MR * ell].fill(kernels::QA_ZERO);
    }
}

// ---------------------------------------------------------------------------
// Transposed variants.
// ---------------------------------------------------------------------------

/// `C = Aᵀ (k×m)ᵀ·B`, i.e. `A` is `k×m` and the result is `m×n`.
/// Used for weight gradients: `dW = Xᵀ · dY`.
///
/// Structured as rank-1 updates `C += a_p ⊗ b_p`. Rows of `A` that are
/// mostly zero (common after ReLU masks) keep a per-element skip; dense
/// rows run branch-free — a branch per element on dense gradients was a
/// measured pessimization. The dense path multiplies by the zeros it no
/// longer skips, which is bit-identical for finite inputs except that
/// `-0.0 + 0.0` normalizes to `+0.0` (and non-finite `B` rows propagate
/// NaN where the skip used to mask them).
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.cols(), b.cols());
    gemm_tn_acc(a, b, &mut c);
    c
}

/// `C += Aᵀ·B` into a caller-retained accumulator — the training engine's
/// weight-gradient form (`gw += Xᵀ·dY` straight into the layer's grad
/// matrix, no temporary). The sparsity census lives in a thread-local
/// [`scratch`] buffer, so warm calls make no heap allocations; the
/// per-element accumulation order is the same as [`gemm_tn`]'s.
pub fn gemm_tn_acc(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (k, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "gemm_tn: inner dims");
    assert_eq!(c.shape(), (m, n), "gemm_tn_acc: output shape");
    let av = a.as_slice();
    let bv = b.as_slice();
    // Per-row sparsity census: one pass over A decides, row by row,
    // whether the skip loop or the dense loop runs. Stored as 0.0/1.0 in
    // a scratch checkout (the stack is f32-typed) to keep warm calls
    // allocation-free.
    scratch::with_f32(k, |census| {
        for (p, flag) in census.iter_mut().enumerate() {
            let zeros = av[p * m..(p + 1) * m].iter().filter(|&&x| x == 0.0).count();
            *flag = if 2 * zeros >= m { 1.0 } else { 0.0 };
        }
        let mz: &[f32] = census;
        let p = pool::current();
        if kernels::active() == KernelKind::Serial
            || 2 * m * k * n < parallel_flop_threshold()
            || p.threads() == 1
        {
            gemm_tn_band(av, bv, c.as_mut_slice(), 0, m, k, m, n, mz);
            return;
        }
        let band = band_rows(m, p.threads());
        let n_bands = m.div_ceil(band);
        let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
        p.run(n_bands, &|t| {
            let i0 = t * band;
            let rows = band.min(m - i0);
            // SAFETY: disjoint row bands of `c`; `run` blocks until done.
            let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
            gemm_tn_band(av, bv, cv, i0, rows, k, m, n, mz);
        });
    });
}

/// Rank-1-update band: `C[i0..i0+rows] += Σ_p a_p[i0..] ⊗ b_p`. The `p`
/// loop stays outermost so per-element accumulation order matches the
/// serial kernel exactly (thread-count-invariant results).
#[allow(clippy::too_many_arguments)]
fn gemm_tn_band(
    av: &[f32],
    bv: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    m: usize,
    n: usize,
    mostly_zero: &[f32],
) {
    for p in 0..k {
        let arow = &av[p * m + i0..p * m + i0 + rows];
        let brow = &bv[p * n..(p + 1) * n];
        if mostly_zero[p] != 0.0 {
            for (i, &x) in arow.iter().enumerate() {
                if x == 0.0 {
                    continue; // skip loop: row is mostly ReLU zeros
                }
                axpy_slice(x, brow, &mut cv[i * n..(i + 1) * n]);
            }
        } else {
            for (i, &x) in arow.iter().enumerate() {
                axpy_slice(x, brow, &mut cv[i * n..(i + 1) * n]);
            }
        }
    }
}

/// `C = A (m×k) · Bᵀ` where `B` is `n×k`. Used for input gradients:
/// `dX = dY · Wᵀ` with `W` stored `k_in×k_out`… kept general.
///
/// Each output row is a bundle of dot products, computed independently —
/// row-band dispatch is trivially bit-identical to the serial loop.
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    gemm_nt_epi(a, b, Epilogue::None)
}

/// [`gemm_nt`] into a caller-retained output (`c` resized — grow-only —
/// and fully overwritten; no zeroing needed, the dot kernel assigns).
pub fn gemm_nt_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    gemm_nt_epi_into(a, b, Epilogue::None, c)
}

/// `C += A·Bᵀ` into a caller-retained accumulator — the training
/// engine's input-gradient form (`dX += dZ·Wᵀ` accumulated across leaves
/// and tree levels without a temporary per term). Each element receives
/// exactly one `+=` of its fully-reduced dot product, so band dispatch is
/// bit-identical to the serial loop at every thread count, like
/// [`gemm_nt`] itself.
pub fn gemm_nt_acc(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, k) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(k, kb, "gemm_nt_acc: inner dims");
    assert_eq!(c.shape(), (m, n), "gemm_nt_acc: output shape");
    let av = a.as_slice();
    let bv = b.as_slice();
    let p = pool::current();
    if kernels::active() == KernelKind::Serial
        || 2 * m * k * n < parallel_flop_threshold()
        || p.threads() == 1
    {
        gemm_nt_band_acc(av, bv, c.as_mut_slice(), 0, m, k, n);
        return;
    }
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    p.run(n_bands, &|t| {
        let i0 = t * band;
        let rows = band.min(m - i0);
        // SAFETY: disjoint row bands of `c`; `run` blocks until done.
        let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        gemm_nt_band_acc(av, bv, cv, i0, rows, k, n);
    });
}

/// Accumulating twin of [`gemm_nt_band`]: `crow[j] += arow · bv_j`.
#[allow(clippy::too_many_arguments)]
fn gemm_nt_band_acc(
    av: &[f32],
    bv: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
) {
    for i in 0..rows {
        let arow = &av[(i0 + i) * k..(i0 + i + 1) * k];
        let crow = &mut cv[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &bv[j * k..(j + 1) * k];
            let b1 = &bv[(j + 1) * k..(j + 2) * k];
            let b2 = &bv[(j + 2) * k..(j + 3) * k];
            let b3 = &bv[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (p, &x) in arow.iter().enumerate() {
                s0 += x * b0[p];
                s1 += x * b1[p];
                s2 += x * b2[p];
                s3 += x * b3[p];
            }
            crow[j] += s0;
            crow[j + 1] += s1;
            crow[j + 2] += s2;
            crow[j + 3] += s3;
            j += 4;
        }
        while j < n {
            crow[j] += dot(arow, &bv[j * k..(j + 1) * k]);
            j += 1;
        }
    }
}

/// `C = relu(A·Bᵀ + bias)` with bias and ReLU fused into the dot
/// kernel's store (`C` is write-only here, so the fusion costs nothing
/// and deletes two elementwise passes). Same dispatch and band
/// bit-identity story as [`gemm_nt`].
pub fn gemm_nt_bias_relu(a: &Matrix, b: &Matrix, bias: &[f32]) -> Matrix {
    assert_eq!(bias.len(), b.rows(), "gemm_nt_bias_relu: bias length mismatch");
    gemm_nt_epi(a, b, Epilogue::BiasRelu(bias))
}

fn gemm_nt_epi(a: &Matrix, b: &Matrix, epi: Epilogue) -> Matrix {
    let mut c = Matrix::zeros(0, 0);
    gemm_nt_epi_into(a, b, epi, &mut c);
    c
}

fn gemm_nt_epi_into(a: &Matrix, b: &Matrix, epi: Epilogue, c: &mut Matrix) {
    let (m, k) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(k, kb, "gemm_nt: inner dims");
    c.resize(m, n);
    let av = a.as_slice();
    let bv = b.as_slice();
    let p = pool::current();
    if kernels::active() == KernelKind::Serial
        || 2 * m * k * n < parallel_flop_threshold()
        || p.threads() == 1
    {
        gemm_nt_band(av, bv, c.as_mut_slice(), 0, m, k, n, epi);
        return;
    }
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    p.run(n_bands, &|t| {
        let i0 = t * band;
        let rows = band.min(m - i0);
        // SAFETY: disjoint row bands of `c`; `run` blocks until done.
        let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        gemm_nt_band(av, bv, cv, i0, rows, k, n, epi);
    });
}

/// Dot-product band with 4 B-rows per pass over each A row (¼ the A-row
/// traffic, 4 independent dot chains — §Perf iteration 1). The store is
/// a plain assignment, so the epilogue fuses for free: `crow[j] =
/// epi.apply(j, s)` is the same arithmetic as storing `s` and running a
/// separate pass.
#[allow(clippy::too_many_arguments)]
fn gemm_nt_band(
    av: &[f32],
    bv: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    epi: Epilogue,
) {
    for i in 0..rows {
        let arow = &av[(i0 + i) * k..(i0 + i + 1) * k];
        gemm_nt_row(arow, bv, &mut cv[i * n..(i + 1) * n], k, n, epi);
    }
}

/// One output row of the `nt` kernel: `crow[j] = epi(arow · bv_j)`.
/// `pub(crate)` so fused row passes (the FFF training engine's backward
/// mega-pass) can produce exactly the bits [`gemm_nt_into`] would.
pub(crate) fn gemm_nt_row(
    arow: &[f32],
    bv: &[f32],
    crow: &mut [f32],
    k: usize,
    n: usize,
    epi: Epilogue,
) {
    let mut j = 0;
    while j + 4 <= n {
        let b0 = &bv[j * k..(j + 1) * k];
        let b1 = &bv[(j + 1) * k..(j + 2) * k];
        let b2 = &bv[(j + 2) * k..(j + 3) * k];
        let b3 = &bv[(j + 3) * k..(j + 4) * k];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        for (p, &x) in arow.iter().enumerate() {
            s0 += x * b0[p];
            s1 += x * b1[p];
            s2 += x * b2[p];
            s3 += x * b3[p];
        }
        crow[j] = epi.apply(j, s0);
        crow[j + 1] = epi.apply(j + 1, s1);
        crow[j + 2] = epi.apply(j + 2, s2);
        crow[j + 3] = epi.apply(j + 3, s3);
        j += 4;
    }
    while j < n {
        crow[j] = epi.apply(j, dot(arow, &bv[j * k..(j + 1) * k]));
        j += 1;
    }
}

/// `C = epi(Xrows · Bᵀ)` where left-operand row `i` is `x.row(rows[i])`:
/// the gather is fused into the kernel, so no copied input panel exists
/// at all. Single-threaded by design — the leaf-bucket callers are
/// already pool tasks (a nested region would run inline anyway). This is
/// the banded/serial-kind leaf path; the packed kind uses
/// [`gemm_packed_gather_epi`].
pub fn gemm_nt_gather_epi(x: &Matrix, rows: &[usize], bt: &Matrix, c: &mut [f32], epi: Epilogue) {
    let k = x.cols();
    let (n, kb) = bt.shape();
    assert_eq!(k, kb, "gemm_nt_gather: inner dims");
    assert!(c.len() >= rows.len() * n, "gemm_nt_gather: short output");
    if let Epilogue::Bias(bb) | Epilogue::BiasRelu(bb) = epi {
        assert!(bb.len() >= n, "gemm_nt_gather: short bias");
    }
    let bv = bt.as_slice();
    for (i, &r) in rows.iter().enumerate() {
        gemm_nt_row(x.row(r), bv, &mut c[i * n..(i + 1) * n], k, n, epi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a.get(i, p) as f64 * b.get(p, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(m.as_mut_slice(), 0.0, 1.0);
        m
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Rng::seed_from_u64(1);
        let shapes = [(1, 1, 1), (3, 5, 7), (4, 4, 4), (17, 33, 9), (64, 300, 10), (5, 1, 5)];
        for &(m, k, n) in &shapes {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let c = gemm(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-3, "({m},{k},{n}) diff={}", c.max_abs_diff(&c0));
        }
    }

    #[test]
    fn gemm_packed_matches_naive_ragged_shapes() {
        let mut rng = Rng::seed_from_u64(11);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (17, 33, 9),
            (33, 257, 31),
            (65, 513, 129),
            (128, 64, 8),
            (31, 300, 17),
            (5, 1, 5),
        ] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let c = gemm_packed(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-3, "({m},{k},{n}) diff={}", c.max_abs_diff(&c0));
        }
    }

    #[test]
    fn forced_kinds_all_match_naive() {
        // Every forced strategy must agree with the oracle on a shape big
        // enough to clear the FLOP threshold (the unit-test twin of the
        // forced-kernel property matrix in tests/properties.rs). The
        // guard clears the forced kind and restores the threshold even
        // if an assert below panics.
        let mut rng = Rng::seed_from_u64(14);
        let a = rand_mat(&mut rng, 80, 200);
        let b = rand_mat(&mut rng, 200, 60);
        let c0 = naive(&a, &b);
        let _serialize = kernels::force_lock();
        let _guard = crate::testing::KernelStateGuard::zero_threshold();
        for kind in KernelKind::ALL {
            kernels::force(Some(kind));
            let c = gemm(&a, &b);
            kernels::force(None);
            assert!(
                c.max_abs_diff(&c0) < 1e-3,
                "kernel {} diff={}",
                kind.name(),
                c.max_abs_diff(&c0)
            );
        }
    }

    #[test]
    fn pooled_paths_are_thread_count_invariant() {
        use crate::tensor::pool::with_threads;
        let mut rng = Rng::seed_from_u64(12);
        let a = rand_mat(&mut rng, 70, 130);
        let b = rand_mat(&mut rng, 130, 50);
        let serial = with_threads(1, || gemm_packed(&a, &b));
        for threads in [2usize, 4, 8] {
            let c = with_threads(threads, || gemm_packed(&a, &b));
            assert_eq!(c, serial, "packed path drifted at {threads} threads");
        }
    }

    #[test]
    fn banded_parallel_is_bit_identical_to_scalar() {
        use crate::tensor::pool::ThreadPool;
        let mut rng = Rng::seed_from_u64(13);
        let a = rand_mat(&mut rng, 67, 90);
        let b = rand_mat(&mut rng, 90, 41);
        let want = gemm_scalar(&a, &b);
        for threads in [1usize, 3, 4] {
            let p = ThreadPool::new(threads);
            let mut c = Matrix::zeros(67, 41);
            banded_parallel(a.as_slice(), b.as_slice(), &mut c, 67, 90, 41, &p);
            assert_eq!(c, want, "banded path diverged from the v1 kernel at {threads} threads");
        }
    }

    #[test]
    fn gemm_bias_adds_bias() {
        let mut rng = Rng::seed_from_u64(2);
        let a = rand_mat(&mut rng, 6, 4);
        let b = rand_mat(&mut rng, 4, 3);
        let bias = vec![1.0, -2.0, 0.5];
        let c = gemm_bias(&a, &b, &bias);
        let mut c0 = naive(&a, &b);
        for r in 0..6 {
            for j in 0..3 {
                c0.set(r, j, c0.get(r, j) + bias[j]);
            }
        }
        assert!(c.max_abs_diff(&c0) < 1e-4);
    }

    #[test]
    fn gemm_bias_relu_matches_manual() {
        let mut rng = Rng::seed_from_u64(21);
        let a = rand_mat(&mut rng, 7, 5);
        let b = rand_mat(&mut rng, 5, 4);
        let bias = vec![0.3, -0.7, 0.0, 1.1];
        let c = gemm_bias_relu(&a, &b, &bias);
        let c0 = naive(&a, &b);
        for r in 0..7 {
            for j in 0..4 {
                let want = (c0.get(r, j) + bias[j]).max(0.0);
                assert!((c.get(r, j) - want).abs() < 1e-4, "({r},{j})");
            }
        }
    }

    #[test]
    fn fused_epilogue_is_bit_identical_to_separate_pass_per_kind() {
        // The v4 contract: for every kernel kind, gemm_bias(_relu) must
        // equal gemm + elementwise pass *bitwise* — the fused store is
        // the same per-element operation order.
        use crate::tensor::kernels::relu_store;
        let mut rng = Rng::seed_from_u64(22);
        let a = rand_mat(&mut rng, 70, 300);
        let b = rand_mat(&mut rng, 300, 50);
        let mut bias = vec![0.0f32; 50];
        rng.fill_normal(&mut bias, 0.0, 1.0);
        bias[7] = -0.0;
        let _serialize = kernels::force_lock();
        let _guard = crate::testing::KernelStateGuard::zero_threshold();
        for kind in KernelKind::ALL {
            kernels::force(Some(kind));
            let fused = gemm_bias(&a, &b, &bias);
            let fused_relu = gemm_bias_relu(&a, &b, &bias);
            let mut unfused = gemm(&a, &b);
            let mut unfused_relu = unfused.clone();
            for r in 0..unfused.rows() {
                for (j, v) in unfused.row_mut(r).iter_mut().enumerate() {
                    *v += bias[j];
                }
                for (j, v) in unfused_relu.row_mut(r).iter_mut().enumerate() {
                    *v = relu_store(*v + bias[j]);
                }
            }
            kernels::force(None);
            assert_eq!(fused, unfused, "gemm_bias drifted under {}", kind.name());
            assert_eq!(fused_relu, unfused_relu, "gemm_bias_relu drifted under {}", kind.name());
        }
    }

    #[test]
    fn gemm_nt_bias_relu_matches_separate_pass() {
        use crate::tensor::kernels::relu_store;
        let mut rng = Rng::seed_from_u64(23);
        let a = rand_mat(&mut rng, 9, 11);
        let b = rand_mat(&mut rng, 6, 11); // n×k
        let mut bias = vec![0.0f32; 6];
        rng.fill_normal(&mut bias, 0.0, 1.0);
        let fused = gemm_nt_bias_relu(&a, &b, &bias);
        let mut unfused = gemm_nt(&a, &b);
        for r in 0..unfused.rows() {
            for (j, v) in unfused.row_mut(r).iter_mut().enumerate() {
                *v = relu_store(*v + bias[j]);
            }
        }
        assert_eq!(fused, unfused, "nt fused store drifted from separate pass");
    }

    #[test]
    fn gather_variants_match_contiguous_paths_bitwise() {
        // The serving-path kernels: gemm_nt_gather_epi ≡ gemm_nt over a
        // gathered copy, and gemm_packed_gather_epi ≡ forced-packed
        // gemm_bias over the same operands — both bit-exact, since the
        // gather only changes where rows are read from.
        let mut rng = Rng::seed_from_u64(24);
        let x = rand_mat(&mut rng, 40, 33);
        let bt = rand_mat(&mut rng, 13, 33); // n×k transposed layout
        let mut bias = vec![0.0f32; 13];
        rng.fill_normal(&mut bias, 0.0, 1.0);
        let rows: Vec<usize> = (0..23).map(|i| (i * 7) % 40).collect();
        let xs = x.gather_rows(&rows);

        let mut got = vec![0.0f32; rows.len() * 13];
        gemm_nt_gather_epi(&x, &rows, &bt, &mut got, Epilogue::BiasRelu(&bias));
        let want = gemm_nt_bias_relu(&xs, &bt, &bias);
        assert_eq!(got, want.as_slice(), "nt gather kernel drifted");

        let _serialize = kernels::force_lock();
        let _guard = crate::testing::KernelStateGuard::zero_threshold();
        kernels::force(Some(KernelKind::Packed));
        let want_packed = gemm_bias(&xs, &bt.transpose(), &bias);
        kernels::force(None);
        let packed = PackedB::pack_nt(&bt);
        assert_eq!((packed.k(), packed.n()), (33, 13));
        let mut got_packed = vec![7.0f32; rows.len() * 13]; // stale scratch: must be overwritten
        gemm_packed_gather_epi(&x, &rows, &packed, &mut got_packed, Epilogue::Bias(&bias));
        assert_eq!(got_packed, want_packed.as_slice(), "prepacked gather path drifted");
    }

    #[test]
    fn scatter_rows_match_gemm_bias_plus_copy() {
        let mut rng = Rng::seed_from_u64(25);
        let a = rand_mat(&mut rng, 6, 9);
        // ReLU-style zeros in A so the skip loop runs.
        let mut a = a;
        for v in a.as_mut_slice().iter_mut() {
            if *v < 0.0 {
                *v = 0.0;
            }
        }
        let b = rand_mat(&mut rng, 9, 5);
        let bias = vec![0.5, -0.25, 0.0, 1.0, -1.0];
        let rows = vec![11usize, 2, 7, 0, 13, 4];
        let mut y = Matrix::full(14, 5, f32::NAN); // scattered rows overwritten, rest untouched
        let yptr = y.as_mut_slice().as_mut_ptr();
        // SAFETY: rows are in bounds of y and the call is single-threaded.
        unsafe {
            gemm_bias_scatter_raw(a.as_slice(), 9, b.as_slice(), 5, &bias, &rows, yptr);
        }
        let want = gemm_bias(&a, &b, &bias);
        for (i, &r) in rows.iter().enumerate() {
            for j in 0..5 {
                assert!(
                    (y.get(r, j) - want.get(i, j)).abs() < 1e-5,
                    "row {r} col {j}: {} vs {}",
                    y.get(r, j),
                    want.get(i, j)
                );
            }
        }
        // Untouched rows stay NaN (the kernel writes only `rows`).
        assert!(y.get(1, 0).is_nan());
    }

    #[test]
    fn into_forms_match_allocating_forms_bitwise() {
        // The training engine's retained-buffer forms are pure memory
        // plumbing: same bits as the allocating wrappers, including when
        // the retained output arrives dirty and oversized. Kernel lock
        // held: both sides of each comparison go through dispatch.
        let _serialize = kernels::force_lock();
        let _guard = crate::testing::KernelStateGuard::zero_threshold();
        let mut rng = Rng::seed_from_u64(41);
        let a = rand_mat(&mut rng, 37, 29);
        let b = rand_mat(&mut rng, 29, 11);
        let bt = rand_mat(&mut rng, 11, 29); // n×k layout for the nt forms
        let mut bias = vec![0.0f32; 11];
        rng.fill_normal(&mut bias, 0.0, 1.0);
        for kind in KernelKind::ALL {
            kernels::force(Some(kind));
            let mut c = Matrix::full(64, 64, 7.0); // dirty + larger than needed
            gemm_into(&a, &b, &mut c);
            assert_eq!(c, gemm(&a, &b), "gemm_into under {}", kind.name());
            gemm_bias_into(&a, &b, &bias, &mut c);
            assert_eq!(c, gemm_bias(&a, &b, &bias), "gemm_bias_into under {}", kind.name());
            gemm_bias_relu_into(&a, &b, &bias, &mut c);
            assert_eq!(
                c,
                gemm_bias_relu(&a, &b, &bias),
                "gemm_bias_relu_into under {}",
                kind.name()
            );
            gemm_nt_into(&a, &bt, &mut c);
            assert_eq!(c, gemm_nt(&a, &bt), "gemm_nt_into under {}", kind.name());
            kernels::force(None);
        }
    }

    #[test]
    fn acc_forms_accumulate_on_top_of_existing_contents() {
        let mut rng = Rng::seed_from_u64(42);
        let a = rand_mat(&mut rng, 9, 13);
        let bt = rand_mat(&mut rng, 7, 13); // n×k
        let mut c = Matrix::full(9, 7, 0.5);
        gemm_nt_acc(&a, &bt, &mut c);
        let mut want = gemm_nt(&a, &bt);
        for v in want.as_mut_slice() {
            *v += 0.5;
        }
        assert!(c.max_abs_diff(&want) < 1e-5, "gemm_nt_acc drifted");

        let at = rand_mat(&mut rng, 13, 9); // k×m
        let b = rand_mat(&mut rng, 13, 7); // k×n
        let mut c2 = Matrix::full(9, 7, -0.25);
        gemm_tn_acc(&at, &b, &mut c2);
        let mut want2 = gemm_tn(&at, &b);
        for v in want2.as_mut_slice() {
            *v += -0.25;
        }
        assert!(c2.max_abs_diff(&want2) < 1e-5, "gemm_tn_acc drifted");
    }

    #[test]
    fn acc_forms_are_thread_count_invariant() {
        use crate::tensor::pool::with_threads;
        let _serialize = kernels::force_lock();
        let _guard = crate::testing::KernelStateGuard::zero_threshold();
        let mut rng = Rng::seed_from_u64(43);
        let a = rand_mat(&mut rng, 61, 90);
        let bt = rand_mat(&mut rng, 33, 90);
        let at = rand_mat(&mut rng, 90, 61);
        let b = rand_mat(&mut rng, 90, 33);
        let serial = with_threads(1, || {
            let mut nt = Matrix::zeros(61, 33);
            gemm_nt_acc(&a, &bt, &mut nt);
            let mut tn = Matrix::zeros(61, 33);
            gemm_tn_acc(&at, &b, &mut tn);
            (nt, tn)
        });
        for threads in [2usize, 4, 8] {
            let got = with_threads(threads, || {
                let mut nt = Matrix::zeros(61, 33);
                gemm_nt_acc(&a, &bt, &mut nt);
                let mut tn = Matrix::zeros(61, 33);
                gemm_tn_acc(&at, &b, &mut tn);
                (nt, tn)
            });
            assert_eq!(got.0, serial.0, "gemm_nt_acc drifted at {threads} threads");
            assert_eq!(got.1, serial.1, "gemm_tn_acc drifted at {threads} threads");
        }
    }

    #[test]
    fn gemm_tn_matches_transpose() {
        let mut rng = Rng::seed_from_u64(3);
        let a = rand_mat(&mut rng, 13, 7); // k×m
        let b = rand_mat(&mut rng, 13, 5); // k×n
        let c = gemm_tn(&a, &b);
        let c0 = naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&c0) < 1e-3);
    }

    #[test]
    fn gemm_tn_sparse_and_dense_rows_agree() {
        // Mix fully-dense rows with ReLU-style sparse rows so both the
        // skip loop and the branch-free loop run; compare to the naive
        // transpose oracle.
        let mut rng = Rng::seed_from_u64(31);
        let mut a = rand_mat(&mut rng, 40, 23); // k×m
        for p in 0..40 {
            if p % 2 == 0 {
                for v in a.row_mut(p).iter_mut() {
                    if *v < 0.4 {
                        *v = 0.0; // mostly-zero row → skip loop
                    }
                }
            }
        }
        let b = rand_mat(&mut rng, 40, 11);
        let c = gemm_tn(&a, &b);
        let c0 = naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&c0) < 1e-3, "diff={}", c.max_abs_diff(&c0));
    }

    #[test]
    fn gemm_nt_matches_transpose() {
        let mut rng = Rng::seed_from_u64(4);
        let a = rand_mat(&mut rng, 9, 11); // m×k
        let b = rand_mat(&mut rng, 6, 11); // n×k
        let c = gemm_nt(&a, &b);
        let c0 = naive(&a, &b.transpose());
        assert!(c.max_abs_diff(&c0) < 1e-3);
    }

    #[test]
    fn gemm_acc_accumulates() {
        let mut rng = Rng::seed_from_u64(5);
        let a = rand_mat(&mut rng, 8, 8);
        let b = rand_mat(&mut rng, 8, 8);
        let mut c = gemm(&a, &b);
        gemm_acc(&a, &b, &mut c);
        let mut c2 = gemm(&a, &b);
        c2.scale(2.0);
        assert!(c.max_abs_diff(&c2) < 1e-4);
    }

    /// Scalar statement of the whole int8 bucket GEMM, built from the
    /// same public pieces the per-sample fallback uses
    /// (`quantize_row_q8_scalar` biased bytes, unbiased by −127, +
    /// `get_q` + the fixed dequant formula) — the packed driver must
    /// match it bit for bit.
    fn naive_quant(
        x: &Matrix,
        rows: &[usize],
        bq: &QuantPackedB,
        bias: &[f32],
        relu: bool,
    ) -> Vec<f32> {
        use crate::tensor::kernels::{quantize_row_q8_scalar, relu_store, QA_ZERO};
        let (k, n) = (bq.k(), bq.n());
        let mut out = vec![0.0f32; rows.len() * n];
        let mut qrow = vec![0u8; k];
        for (i, &r) in rows.iter().enumerate() {
            let sa = quantize_row_q8_scalar(x.row(r), &mut qrow);
            for j in 0..n {
                let mut acc = 0i32;
                for (p, &q) in qrow.iter().enumerate() {
                    acc += (q as i32 - QA_ZERO as i32) * bq.get_q(j, p) as i32;
                }
                let s = sa * bq.scale(j / NR);
                let t = acc as f32 * s + bias[j];
                out[i * n + j] = if relu { relu_store(t) } else { t };
            }
        }
        out
    }

    #[test]
    fn quantize_nt_pins_layout_scales_corr_and_zero_panels() {
        // 10 columns → 2 panels; panel 1 (cols 8..10) all zeros.
        let mut bt = Matrix::zeros(10, 7); // n×k
        for j in 0..8 {
            for p in 0..7 {
                bt.set(j, p, ((j * 7 + p) as f32 - 20.0) * 0.125);
            }
        }
        let bq = QuantPackedB::quantize_nt(&bt);
        assert_eq!((bq.k(), bq.n()), (7, 10));
        // Zero panel: scale 1.0, all-zero bytes (divide-by-zero guard),
        // zero bias-correction terms.
        assert_eq!(bq.scale(1), 1.0);
        for j in 8..10 {
            for p in 0..7 {
                assert_eq!(bq.get_q(j, p), 0, "zero panel byte ({j},{p})");
            }
            assert_eq!(bq.corr_of(j), 0, "zero panel corr ({j})");
        }
        // Correction terms are 127·Σ_p q[j][p] — what the VNNI kernel
        // subtracts to unbias the biased-u8 A side. Zero k-pad bytes in
        // the packed panels must not perturb the sum.
        for j in 0..10 {
            let want: i32 = (0..7).map(|p| bq.get_q(j, p) as i32).sum::<i32>() * 127;
            assert_eq!(bq.corr_of(j), want, "corr ({j})");
        }
        // Panel 0: absmax element quantizes to ±127 exactly; round-trip
        // error ≤ scale/2 (plus float slop) per element.
        let mut absmax = 0.0f32;
        for j in 0..8 {
            for p in 0..7 {
                absmax = absmax.max(bt.get(j, p).abs());
            }
        }
        let s = bq.scale(0);
        assert_eq!(s, absmax / 127.0);
        let mut hit_extreme = false;
        for j in 0..8 {
            for p in 0..7 {
                let q = bq.get_q(j, p);
                assert!((bt.get(j, p) - q as f32 * s).abs() <= 0.5001 * s, "({j},{p})");
                hit_extreme |= q.unsigned_abs() == 127;
            }
        }
        assert!(hit_extreme, "absmax element should land on ±127");
        // Memory: quantized payload is ~a quarter of the f32 panel.
        assert!(bq.bytes() < 10 * 7 * 4 / 2);
    }

    #[test]
    fn quant_gather_matches_scalar_statement_bitwise_per_kind() {
        // The packed int8 driver vs the written-out scalar statement,
        // under every forced kernel kind — integer accumulation plus the
        // fixed dequant store make these exactly equal, which is the
        // invariant the int8 serving mode's determinism rides on.
        let mut rng = Rng::seed_from_u64(61);
        for &(m_src, k, n) in &[(9usize, 5usize, 3usize), (40, 33, 13), (24, 64, 16), (7, 1, 9)] {
            let x = rand_mat(&mut rng, m_src, k);
            let bt = rand_mat(&mut rng, n, k);
            let mut bias = vec![0.0f32; n];
            rng.fill_normal(&mut bias, 0.0, 1.0);
            let bq = QuantPackedB::quantize_nt(&bt);
            let rows: Vec<usize> = (0..(m_src * 2 / 3).max(1)).map(|i| (i * 5) % m_src).collect();
            let want = naive_quant(&x, &rows, &bq, &bias, true);
            let _serialize = kernels::force_lock();
            let _guard = crate::testing::KernelStateGuard::zero_threshold();
            for kind in KernelKind::ALL {
                kernels::force(Some(kind));
                let mut got = vec![f32::NAN; rows.len() * n]; // stale: must be overwritten
                gemm_quant_gather_epi(&x, &rows, &bq, &mut got, Epilogue::BiasRelu(&bias));
                kernels::force(None);
                let (gb, wb): (Vec<u32>, Vec<u32>) = (
                    got.iter().map(|v| v.to_bits()).collect(),
                    want.iter().map(|v| v.to_bits()).collect(),
                );
                assert_eq!(gb, wb, "int8 gather drifted under {} at ({k},{n})", kind.name());
            }
        }
    }

    #[test]
    fn quant_gather_tracks_f32_oracle_loosely() {
        // Not bit-exact against f32 (that's the point of quantizing) but
        // the two must stay close on well-conditioned inputs.
        let mut rng = Rng::seed_from_u64(62);
        let x = rand_mat(&mut rng, 20, 64);
        let bt = rand_mat(&mut rng, 16, 64);
        let bias = vec![0.1f32; 16];
        let bq = QuantPackedB::quantize_nt(&bt);
        let rows: Vec<usize> = (0..20).collect();
        let mut got = vec![0.0f32; 20 * 16];
        gemm_quant_gather_epi(&x, &rows, &bq, &mut got, Epilogue::Bias(&bias));
        let mut want = vec![0.0f32; 20 * 16];
        gemm_nt_gather_epi(&x, &rows, &bt, &mut want, Epilogue::Bias(&bias));
        let max_diff = got
            .iter()
            .zip(&want)
            .map(|(g, w)| (g - w).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2.0, "int8 drifted {max_diff} from f32 at k=64");
        let mean_diff: f32 =
            got.iter().zip(&want).map(|(g, w)| (g - w).abs()).sum::<f32>() / got.len() as f32;
        assert!(mean_diff < 0.3, "int8 mean drift {mean_diff} too large");
    }

    #[test]
    fn quant_scatter_matches_quant_gather_plus_copy() {
        let mut rng = Rng::seed_from_u64(63);
        let m = 6;
        let k = 9;
        let n = 10;
        let mut a = rand_mat(&mut rng, m, k);
        for v in a.as_mut_slice().iter_mut() {
            if *v < 0.0 {
                *v = 0.0; // post-ReLU-shaped input, like the real caller
            }
        }
        let bt = rand_mat(&mut rng, n, k);
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(&mut bias, 0.0, 1.0);
        let bq = QuantPackedB::quantize_nt(&bt);
        let rows = vec![11usize, 2, 7, 0, 13, 4];
        let mut y = Matrix::full(14, n, f32::NAN);
        let yptr = y.as_mut_slice().as_mut_ptr();
        // SAFETY: rows are in bounds of y and the call is single-threaded.
        unsafe {
            gemm_quant_scatter_raw(a.as_slice(), k, &bq, n, &bias, &rows, yptr);
        }
        // Oracle: the contiguous int8 driver over an identity gather.
        let idx: Vec<usize> = (0..m).collect();
        let mut want = vec![0.0f32; m * n];
        gemm_quant_gather_epi(&a, &idx, &bq, &mut want, Epilogue::Bias(&bias));
        for (i, &r) in rows.iter().enumerate() {
            for j in 0..n {
                assert_eq!(
                    y.get(r, j).to_bits(),
                    want[i * n + j].to_bits(),
                    "row {r} col {j} drifted from contiguous int8 driver"
                );
            }
        }
        // Untouched rows stay NaN (the kernel writes only `rows`).
        assert!(y.get(1, 0).is_nan());
    }

    #[test]
    fn fused_leaf_matches_unfused_store_then_requantize_bitwise() {
        // The register-fused leaf path (leaf_quant_l1 + prequant scatter)
        // vs the unfused statement: gather-GEMM the L1 with BiasRelu,
        // requantize each stored f32 row with the scalar row quantizer,
        // then scatter-GEMM the L2 from the same quantized rows. The f32
        // store/load the fused path skips is lossless, so bytes, scales,
        // and final outputs must all carry identical bits. Runs only
        // where the SIMD leaf tile exists (ell == 2·NR and AVX2 kernels
        // active); on other hosts the serving path uses the unfused
        // route this test treats as the oracle.
        use crate::tensor::kernels::quantize_row_q8_scalar;
        let ell = 2 * NR;
        if !fused_leaf_available(ell) {
            return;
        }
        let mut rng = Rng::seed_from_u64(64);
        let (m_src, k) = (13usize, 37usize);
        let n_out = 10usize;
        let x = rand_mat(&mut rng, m_src, k);
        let w1t = rand_mat(&mut rng, ell, k); // leaf L1, n×k
        let w2t = rand_mat(&mut rng, n_out, ell); // leaf L2, n×k
        let mut b1 = vec![0.0f32; ell];
        let mut b2 = vec![0.0f32; n_out];
        rng.fill_normal(&mut b1, 0.0, 1.0);
        rng.fill_normal(&mut b2, 0.0, 1.0);
        let q1 = QuantPackedB::quantize_nt(&w1t);
        let q2 = QuantPackedB::quantize_nt(&w2t);
        let rows = vec![4usize, 0, 11, 7, 2, 9, 12, 1, 5];
        let m = rows.len();
        let mp = m.div_ceil(MR);

        // Fused path.
        let mut qa1 = vec![0u8; mp * MR * ell];
        let mut sa1 = vec![0.0f32; m];
        leaf_quant_l1(&x, &rows, &q1, &b1, &mut qa1, &mut sa1);
        let mut y = Matrix::full(m_src, n_out, f32::NAN);
        // SAFETY: scatter rows are in bounds of y; single-threaded call.
        unsafe {
            gemm_quant_scatter_prequant(&qa1, &sa1, &q2, &b2, &rows, y.as_mut_slice().as_mut_ptr());
        }

        // Unfused oracle: store the ReLU'd hidden block, requantize rows.
        let mut h = vec![f32::NAN; m * ell];
        gemm_quant_gather_epi(&x, &rows, &q1, &mut h, Epilogue::BiasRelu(&b1));
        let mut qa_want = vec![kernels::QA_ZERO; mp * MR * ell];
        let mut sa_want = vec![0.0f32; m];
        for r in 0..m {
            let row = &h[r * ell..(r + 1) * ell];
            sa_want[r] = quantize_row_q8_scalar(row, &mut qa_want[r * ell..(r + 1) * ell]);
        }
        assert_eq!(qa1, qa_want, "fused leaf bytes drifted");
        let sb: Vec<u32> = sa1.iter().map(|v| v.to_bits()).collect();
        let wb: Vec<u32> = sa_want.iter().map(|v| v.to_bits()).collect();
        assert_eq!(sb, wb, "fused leaf scales drifted");
        let mut y_want = Matrix::full(m_src, n_out, f32::NAN);
        // SAFETY: same contract as above.
        unsafe {
            let yp = y_want.as_mut_slice().as_mut_ptr();
            gemm_quant_scatter_raw(&h, ell, &q2, n_out, &b2, &rows, yp);
        }
        for &r in &rows {
            for j in 0..n_out {
                assert_eq!(
                    y.get(r, j).to_bits(),
                    y_want.get(r, j).to_bits(),
                    "fused L2 row {r} col {j} drifted"
                );
            }
        }
    }

    #[test]
    fn threshold_is_tunable() {
        // Under the kernel lock: tests asserting bitwise equality between
        // dispatched GEMMs rely on the threshold holding still.
        let _serialize = kernels::force_lock();
        let before = parallel_flop_threshold();
        set_parallel_flop_threshold(123);
        assert_eq!(parallel_flop_threshold(), 123);
        set_parallel_flop_threshold(before);
    }
}
