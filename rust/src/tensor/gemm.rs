//! GEMM drivers for the native engine (v3: explicit-SIMD microkernel).
//!
//! Layout is row-major everywhere. Execution tiers (see EXPERIMENTS.md
//! §Perf for the measured iteration log naive → ikj → packed+parallel →
//! intrinsic microkernel):
//!
//! 1. **Small** (below [`parallel_flop_threshold`]) or kind `serial`: the
//!    v1 serial kernel — classic `i-k-j` loop order with a 4-row unroll
//!    and k-blocking; the innermost loop walks contiguous rows of `B` and
//!    `C` and auto-vectorizes. Zero dispatch overhead, so
//!    experiment-scale matrices are not pessimized.
//! 2. **Large**: row bands of `C` are dispatched as work-stealing tasks on
//!    the [`super::pool`] thread pool. Band boundaries never change the
//!    per-element accumulation order, so results are **bit-identical
//!    across thread counts** for every kernel kind.
//! 3. Within a band, the strategy is [`kernels::active`]
//!    (`FFF_GEMM_KERNEL=packed|banded|serial` overrides, tests force it
//!    per case):
//!    * `packed` (default) — `A`/`B` panels packed into cache-blocked
//!      buffers and the 4x8 microkernel from the detected
//!      [`kernels::table`]: explicit AVX2/FMA or NEON intrinsics, with
//!      the auto-vectorized tile as the portable fallback;
//!    * `banded` — the v1 `i-k-j` kernel applied per band (kept as the
//!      comparison baseline and for hosts where packing buys nothing).
//!
//!    The packed-vs-banded runtime calibration from iteration 2 is gone:
//!    it existed because auto-vectorizers disagreed 4x on the
//!    microkernel, and the intrinsic tile removed that variance
//!    (EXPERIMENTS.md §Perf iteration 3).

use super::kernels::{self, KernelKind, MR, NR};
use super::ops::{axpy_slice, dot};
use super::pool::{self, SendPtr};
use super::Matrix;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Panel size along `k` — a `KC × NR` micro-panel of `B` (8 KiB) plus a
/// `KC × MR` micro-panel of `A` stays resident in L1.
const KC: usize = 256;

/// 2·m·k·n below which GEMMs stay on the serial v1 kernel. Defaults to
/// 4 MFLOP (~a 128³ product); tune with [`set_parallel_flop_threshold`].
static PAR_FLOP_THRESHOLD: AtomicUsize = AtomicUsize::new(4_000_000);

/// Current FLOP cutoff between the serial small path and the pooled path.
pub fn parallel_flop_threshold() -> usize {
    PAR_FLOP_THRESHOLD.load(Ordering::Relaxed)
}

/// Set the FLOP cutoff (2·m·k·n) above which GEMMs use the thread pool.
/// `0` sends everything through the pooled path (used by tests/benches).
pub fn set_parallel_flop_threshold(flops: usize) {
    PAR_FLOP_THRESHOLD.store(flops, Ordering::Relaxed);
}

/// `C = A (m×k) · B (k×n)`.
pub fn gemm(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    gemm_acc(a, b, &mut c);
    c
}

/// `C = A·B + bias` where `bias` is a length-`n` row broadcast over rows.
pub fn gemm_bias(a: &Matrix, b: &Matrix, bias: &[f32]) -> Matrix {
    assert_eq!(bias.len(), b.cols(), "gemm_bias: bias length mismatch");
    let mut c = Matrix::zeros(a.rows(), b.cols());
    for r in 0..c.rows() {
        c.row_mut(r).copy_from_slice(bias);
    }
    gemm_acc(a, b, &mut c);
    c
}

/// `C += A·B` (accumulating GEMM core, auto-dispatched).
pub fn gemm_acc(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    let (m, ka) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(ka, kb, "gemm: inner dims {ka} vs {kb}");
    assert_eq!(c.shape(), (m, n), "gemm: output shape");
    let k = ka;
    let kind = kernels::active();
    if kind == KernelKind::Serial || 2 * m * k * n < parallel_flop_threshold() {
        seed_kernel(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, k, n);
        return;
    }
    let p = pool::current();
    match kind {
        KernelKind::Packed => packed_parallel(a.as_slice(), b.as_slice(), c, m, k, n, &p),
        KernelKind::Banded => banded_parallel(a.as_slice(), b.as_slice(), c, m, k, n, &p),
        KernelKind::Serial => unreachable!("serial handled above"),
    }
}

/// `C = A·B` forced through the v1 serial kernel (bench baseline, and
/// what `FFF_GEMM_KERNEL=serial` routes everything to).
pub fn gemm_scalar(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(k, b.rows(), "gemm_scalar: inner dims");
    let mut c = Matrix::zeros(m, n);
    seed_kernel(a.as_slice(), b.as_slice(), c.as_mut_slice(), m, k, n);
    c
}

/// `C = A·B` forced through the packed microkernel path on the current
/// pool, regardless of size (property tests and bench suite).
pub fn gemm_packed(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let n = b.cols();
    assert_eq!(k, b.rows(), "gemm_packed: inner dims");
    let mut c = Matrix::zeros(m, n);
    let p = pool::current();
    packed_parallel(a.as_slice(), b.as_slice(), &mut c, m, k, n, &p);
    c
}

/// Rows per parallel band: aim for ~4 tasks per thread (work stealing
/// evens out the tail), within [MR, 64], rounded up to a whole number of
/// MR-row micro-panels. Band boundaries do not affect numerics.
fn band_rows(m: usize, threads: usize) -> usize {
    let target = m.div_ceil(threads.max(1) * 4).clamp(MR, 64);
    target.div_ceil(MR) * MR
}

// ---------------------------------------------------------------------------
// Banded path: the v1 i-k-j kernel over pool-dispatched row bands.
// ---------------------------------------------------------------------------

/// The v1 serial kernel: `C += A·B` over raw row-major slices. Per element
/// the accumulation order is `p` ascending within each k-block — identical
/// whether invoked on a full matrix or any row band of it.
fn seed_kernel(av: &[f32], bv: &[f32], cv: &mut [f32], m: usize, k: usize, n: usize) {
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        let mut i = 0;
        // 4-row unrolled macro-kernel.
        while i + 4 <= m {
            let (a0, a1, a2, a3) = (
                &av[i * k..(i + 1) * k],
                &av[(i + 1) * k..(i + 2) * k],
                &av[(i + 2) * k..(i + 3) * k],
                &av[(i + 3) * k..(i + 4) * k],
            );
            for p in k0..k1 {
                let (x0, x1, x2, x3) = (a0[p], a1[p], a2[p], a3[p]);
                let brow = &bv[p * n..p * n + n];
                let (c01, rest) = cv[i * n..].split_at_mut(2 * n);
                let (c0, c1) = c01.split_at_mut(n);
                let (c2, c3rest) = rest.split_at_mut(n);
                let c3 = &mut c3rest[..n];
                for (j, &bj) in brow.iter().enumerate() {
                    c0[j] += x0 * bj;
                    c1[j] += x1 * bj;
                    c2[j] += x2 * bj;
                    c3[j] += x3 * bj;
                }
            }
            i += 4;
        }
        // Remainder rows.
        while i < m {
            let arow = &av[i * k..(i + 1) * k];
            let crow = &mut cv[i * n..(i + 1) * n];
            for p in k0..k1 {
                let x = arow[p];
                let brow = &bv[p * n..p * n + n];
                for (cj, &bj) in crow.iter_mut().zip(brow) {
                    *cj += x * bj;
                }
            }
            i += 1;
        }
    }
}

/// Row-band parallel wrapper around [`seed_kernel`].
fn banded_parallel(
    av: &[f32],
    bv: &[f32],
    c: &mut Matrix,
    m: usize,
    k: usize,
    n: usize,
    p: &pool::ThreadPool,
) {
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    p.run(n_bands, &|t| {
        let i0 = t * band;
        let rows = band.min(m - i0);
        // SAFETY: bands are disjoint row ranges of `c`, and `run` returns
        // before `c` is touched again by the caller.
        let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        seed_kernel(&av[i0 * k..(i0 + rows) * k], bv, cv, rows, k, n);
    });
}

// ---------------------------------------------------------------------------
// Packed path: cache-blocked panels + the dispatched 4x8 microkernel.
// ---------------------------------------------------------------------------

/// Pack a `kc`-deep panel of `B` (rows `k0..k0+kc`, all `n` columns) into
/// NR-wide micro-panels: `bpack[jp][p][c]`, zero-padded in the tail panel.
fn pack_b(bv: &[f32], n: usize, k0: usize, kc: usize, bpack: &mut [f32]) {
    let n_panels = n.div_ceil(NR);
    for jp in 0..n_panels {
        let j0 = jp * NR;
        let nr = NR.min(n - j0);
        let dst = &mut bpack[jp * kc * NR..(jp + 1) * kc * NR];
        for p in 0..kc {
            let src = &bv[(k0 + p) * n + j0..(k0 + p) * n + j0 + nr];
            let d = &mut dst[p * NR..p * NR + NR];
            d[..nr].copy_from_slice(src);
            d[nr..].fill(0.0);
        }
    }
}

/// Pack `rows` rows of `A` starting at `i0`, columns `k0..k0+kc`, into
/// MR-tall micro-panels: `apack[ip][p][r]`, zero-padded in the tail panel.
fn pack_a(av: &[f32], k: usize, i0: usize, rows: usize, k0: usize, kc: usize, apack: &mut [f32]) {
    let m_panels = rows.div_ceil(MR);
    for ip in 0..m_panels {
        let r0 = ip * MR;
        let mr = MR.min(rows - r0);
        let dst = &mut apack[ip * kc * MR..(ip + 1) * kc * MR];
        for p in 0..kc {
            let d = &mut dst[p * MR..p * MR + MR];
            for (r, dr) in d[..mr].iter_mut().enumerate() {
                *dr = av[(i0 + r0 + r) * k + k0 + p];
            }
            d[mr..].fill(0.0);
        }
    }
}

/// Packed serial band: pack the band's rows of `A`, then run `micro`
/// (the microkernel from [`kernels::table`]) over every (MR row-panel ×
/// NR col-panel) tile.
#[allow(clippy::too_many_arguments)]
fn packed_band(
    av: &[f32],
    bpack: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
    k0: usize,
    kc: usize,
    micro: kernels::Micro4x8,
) {
    let m_panels = rows.div_ceil(MR);
    let n_panels = n.div_ceil(NR);
    let mut apack = vec![0.0f32; m_panels * MR * kc];
    pack_a(av, k, i0, rows, k0, kc, &mut apack);
    for ip in 0..m_panels {
        let r0 = ip * MR;
        let mr = MR.min(rows - r0);
        let ap = &apack[ip * kc * MR..(ip + 1) * kc * MR];
        for jp in 0..n_panels {
            let j0 = jp * NR;
            let nr = NR.min(n - j0);
            let bp = &bpack[jp * kc * NR..(jp + 1) * kc * NR];
            micro(kc, ap, bp, &mut cv[r0 * n + j0..], n, mr, nr);
        }
    }
}

/// Packed + pooled `C += A·B`: per k-panel, `B` is packed once (shared,
/// read-only) and row bands are dispatched as pool tasks, each packing its
/// own slice of `A` into a thread-local buffer.
fn packed_parallel(
    av: &[f32],
    bv: &[f32],
    c: &mut Matrix,
    m: usize,
    k: usize,
    n: usize,
    p: &pool::ThreadPool,
) {
    let micro = kernels::table().micro_4x8;
    let n_panels = n.div_ceil(NR);
    let kc_max = k.min(KC);
    let mut bpack = vec![0.0f32; n_panels * kc_max * NR];
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    for k0 in (0..k).step_by(KC) {
        let kc = KC.min(k - k0);
        pack_b(bv, n, k0, kc, &mut bpack);
        let bp: &[f32] = &bpack[..n_panels * kc * NR];
        p.run(n_bands, &|t| {
            let i0 = t * band;
            let rows = band.min(m - i0);
            // SAFETY: bands are disjoint row ranges of `c`, and `run`
            // returns before `c` is touched again by the caller.
            let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
            packed_band(av, bp, cv, i0, rows, k, n, k0, kc, micro);
        });
    }
}

// ---------------------------------------------------------------------------
// Transposed variants.
// ---------------------------------------------------------------------------

/// `C = Aᵀ (k×m)ᵀ·B`, i.e. `A` is `k×m` and the result is `m×n`.
/// Used for weight gradients: `dW = Xᵀ · dY`.
///
/// Structured as rank-1 updates `C += a_p ⊗ b_p`. Rows of `A` that are
/// mostly zero (common after ReLU masks) keep a per-element skip; dense
/// rows run branch-free — a branch per element on dense gradients was a
/// measured pessimization. The dense path multiplies by the zeros it no
/// longer skips, which is bit-identical for finite inputs except that
/// `-0.0 + 0.0` normalizes to `+0.0` (and non-finite `B` rows propagate
/// NaN where the skip used to mask them).
pub fn gemm_tn(a: &Matrix, b: &Matrix) -> Matrix {
    let (k, m) = a.shape();
    let (kb, n) = b.shape();
    assert_eq!(k, kb, "gemm_tn: inner dims");
    let mut c = Matrix::zeros(m, n);
    let av = a.as_slice();
    let bv = b.as_slice();
    // Per-row sparsity census: one pass over A decides, row by row,
    // whether the skip loop or the dense loop runs.
    let mostly_zero: Vec<bool> = (0..k)
        .map(|p| {
            let zeros = av[p * m..(p + 1) * m].iter().filter(|&&x| x == 0.0).count();
            2 * zeros >= m
        })
        .collect();
    let p = pool::current();
    if kernels::active() == KernelKind::Serial
        || 2 * m * k * n < parallel_flop_threshold()
        || p.threads() == 1
    {
        gemm_tn_band(av, bv, c.as_mut_slice(), 0, m, k, m, n, &mostly_zero);
        return c;
    }
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    let mz: &[bool] = &mostly_zero;
    p.run(n_bands, &|t| {
        let i0 = t * band;
        let rows = band.min(m - i0);
        // SAFETY: disjoint row bands of `c`; `run` blocks until done.
        let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        gemm_tn_band(av, bv, cv, i0, rows, k, m, n, mz);
    });
    c
}

/// Rank-1-update band: `C[i0..i0+rows] += Σ_p a_p[i0..] ⊗ b_p`. The `p`
/// loop stays outermost so per-element accumulation order matches the
/// serial kernel exactly (thread-count-invariant results).
#[allow(clippy::too_many_arguments)]
fn gemm_tn_band(
    av: &[f32],
    bv: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    m: usize,
    n: usize,
    mostly_zero: &[bool],
) {
    for p in 0..k {
        let arow = &av[p * m + i0..p * m + i0 + rows];
        let brow = &bv[p * n..(p + 1) * n];
        if mostly_zero[p] {
            for (i, &x) in arow.iter().enumerate() {
                if x == 0.0 {
                    continue; // skip loop: row is mostly ReLU zeros
                }
                axpy_slice(x, brow, &mut cv[i * n..(i + 1) * n]);
            }
        } else {
            for (i, &x) in arow.iter().enumerate() {
                axpy_slice(x, brow, &mut cv[i * n..(i + 1) * n]);
            }
        }
    }
}

/// `C = A (m×k) · Bᵀ` where `B` is `n×k`. Used for input gradients:
/// `dX = dY · Wᵀ` with `W` stored `k_in×k_out`… kept general.
///
/// Each output row is a bundle of dot products, computed independently —
/// row-band dispatch is trivially bit-identical to the serial loop.
pub fn gemm_nt(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k) = a.shape();
    let (n, kb) = b.shape();
    assert_eq!(k, kb, "gemm_nt: inner dims");
    let mut c = Matrix::zeros(m, n);
    let av = a.as_slice();
    let bv = b.as_slice();
    let p = pool::current();
    if kernels::active() == KernelKind::Serial
        || 2 * m * k * n < parallel_flop_threshold()
        || p.threads() == 1
    {
        gemm_nt_band(av, bv, c.as_mut_slice(), 0, m, k, n);
        return c;
    }
    let band = band_rows(m, p.threads());
    let n_bands = m.div_ceil(band);
    let cptr = SendPtr(c.as_mut_slice().as_mut_ptr());
    p.run(n_bands, &|t| {
        let i0 = t * band;
        let rows = band.min(m - i0);
        // SAFETY: disjoint row bands of `c`; `run` blocks until done.
        let cv = unsafe { std::slice::from_raw_parts_mut(cptr.0.add(i0 * n), rows * n) };
        gemm_nt_band(av, bv, cv, i0, rows, k, n);
    });
    c
}

/// Dot-product band with 4 B-rows per pass over each A row (¼ the A-row
/// traffic, 4 independent dot chains — §Perf iteration 1).
fn gemm_nt_band(
    av: &[f32],
    bv: &[f32],
    cv: &mut [f32],
    i0: usize,
    rows: usize,
    k: usize,
    n: usize,
) {
    for i in 0..rows {
        let arow = &av[(i0 + i) * k..(i0 + i + 1) * k];
        let crow = &mut cv[i * n..(i + 1) * n];
        let mut j = 0;
        while j + 4 <= n {
            let b0 = &bv[j * k..(j + 1) * k];
            let b1 = &bv[(j + 1) * k..(j + 2) * k];
            let b2 = &bv[(j + 2) * k..(j + 3) * k];
            let b3 = &bv[(j + 3) * k..(j + 4) * k];
            let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
            for (p, &x) in arow.iter().enumerate() {
                s0 += x * b0[p];
                s1 += x * b1[p];
                s2 += x * b2[p];
                s3 += x * b3[p];
            }
            crow[j] = s0;
            crow[j + 1] = s1;
            crow[j + 2] = s2;
            crow[j + 3] = s3;
            j += 4;
        }
        while j < n {
            crow[j] = dot(arow, &bv[j * k..(j + 1) * k]);
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn naive(a: &Matrix, b: &Matrix) -> Matrix {
        let (m, k) = a.shape();
        let n = b.cols();
        let mut c = Matrix::zeros(m, n);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0f64;
                for p in 0..k {
                    acc += a.get(i, p) as f64 * b.get(p, j) as f64;
                }
                c.set(i, j, acc as f32);
            }
        }
        c
    }

    fn rand_mat(rng: &mut Rng, r: usize, c: usize) -> Matrix {
        let mut m = Matrix::zeros(r, c);
        rng.fill_normal(m.as_mut_slice(), 0.0, 1.0);
        m
    }

    #[test]
    fn gemm_matches_naive_various_shapes() {
        let mut rng = Rng::seed_from_u64(1);
        let shapes = [(1, 1, 1), (3, 5, 7), (4, 4, 4), (17, 33, 9), (64, 300, 10), (5, 1, 5)];
        for &(m, k, n) in &shapes {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let c = gemm(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-3, "({m},{k},{n}) diff={}", c.max_abs_diff(&c0));
        }
    }

    #[test]
    fn gemm_packed_matches_naive_ragged_shapes() {
        let mut rng = Rng::seed_from_u64(11);
        for &(m, k, n) in &[
            (1, 1, 1),
            (3, 5, 7),
            (17, 33, 9),
            (33, 257, 31),
            (65, 513, 129),
            (128, 64, 8),
            (31, 300, 17),
            (5, 1, 5),
        ] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let c = gemm_packed(&a, &b);
            let c0 = naive(&a, &b);
            assert!(c.max_abs_diff(&c0) < 1e-3, "({m},{k},{n}) diff={}", c.max_abs_diff(&c0));
        }
    }

    #[test]
    fn forced_kinds_all_match_naive() {
        // Every forced strategy must agree with the oracle on a shape big
        // enough to clear the FLOP threshold (the unit-test twin of the
        // forced-kernel property matrix in tests/properties.rs). The
        // guard clears the forced kind and restores the threshold even
        // if an assert below panics.
        let mut rng = Rng::seed_from_u64(14);
        let a = rand_mat(&mut rng, 80, 200);
        let b = rand_mat(&mut rng, 200, 60);
        let c0 = naive(&a, &b);
        let _serialize = kernels::force_lock();
        let _guard = crate::testing::KernelStateGuard::zero_threshold();
        for kind in KernelKind::ALL {
            kernels::force(Some(kind));
            let c = gemm(&a, &b);
            kernels::force(None);
            assert!(
                c.max_abs_diff(&c0) < 1e-3,
                "kernel {} diff={}",
                kind.name(),
                c.max_abs_diff(&c0)
            );
        }
    }

    #[test]
    fn pooled_paths_are_thread_count_invariant() {
        use crate::tensor::pool::with_threads;
        let mut rng = Rng::seed_from_u64(12);
        let a = rand_mat(&mut rng, 70, 130);
        let b = rand_mat(&mut rng, 130, 50);
        let serial = with_threads(1, || gemm_packed(&a, &b));
        for threads in [2usize, 4, 8] {
            let c = with_threads(threads, || gemm_packed(&a, &b));
            assert_eq!(c, serial, "packed path drifted at {threads} threads");
        }
    }

    #[test]
    fn banded_parallel_is_bit_identical_to_scalar() {
        use crate::tensor::pool::ThreadPool;
        let mut rng = Rng::seed_from_u64(13);
        let a = rand_mat(&mut rng, 67, 90);
        let b = rand_mat(&mut rng, 90, 41);
        let want = gemm_scalar(&a, &b);
        for threads in [1usize, 3, 4] {
            let p = ThreadPool::new(threads);
            let mut c = Matrix::zeros(67, 41);
            banded_parallel(a.as_slice(), b.as_slice(), &mut c, 67, 90, 41, &p);
            assert_eq!(c, want, "banded path diverged from the v1 kernel at {threads} threads");
        }
    }

    #[test]
    fn gemm_bias_adds_bias() {
        let mut rng = Rng::seed_from_u64(2);
        let a = rand_mat(&mut rng, 6, 4);
        let b = rand_mat(&mut rng, 4, 3);
        let bias = vec![1.0, -2.0, 0.5];
        let c = gemm_bias(&a, &b, &bias);
        let mut c0 = naive(&a, &b);
        for r in 0..6 {
            for j in 0..3 {
                c0.set(r, j, c0.get(r, j) + bias[j]);
            }
        }
        assert!(c.max_abs_diff(&c0) < 1e-4);
    }

    #[test]
    fn gemm_tn_matches_transpose() {
        let mut rng = Rng::seed_from_u64(3);
        let a = rand_mat(&mut rng, 13, 7); // k×m
        let b = rand_mat(&mut rng, 13, 5); // k×n
        let c = gemm_tn(&a, &b);
        let c0 = naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&c0) < 1e-3);
    }

    #[test]
    fn gemm_tn_sparse_and_dense_rows_agree() {
        // Mix fully-dense rows with ReLU-style sparse rows so both the
        // skip loop and the branch-free loop run; compare to the naive
        // transpose oracle.
        let mut rng = Rng::seed_from_u64(31);
        let mut a = rand_mat(&mut rng, 40, 23); // k×m
        for p in 0..40 {
            if p % 2 == 0 {
                for v in a.row_mut(p).iter_mut() {
                    if *v < 0.4 {
                        *v = 0.0; // mostly-zero row → skip loop
                    }
                }
            }
        }
        let b = rand_mat(&mut rng, 40, 11);
        let c = gemm_tn(&a, &b);
        let c0 = naive(&a.transpose(), &b);
        assert!(c.max_abs_diff(&c0) < 1e-3, "diff={}", c.max_abs_diff(&c0));
    }

    #[test]
    fn gemm_nt_matches_transpose() {
        let mut rng = Rng::seed_from_u64(4);
        let a = rand_mat(&mut rng, 9, 11); // m×k
        let b = rand_mat(&mut rng, 6, 11); // n×k
        let c = gemm_nt(&a, &b);
        let c0 = naive(&a, &b.transpose());
        assert!(c.max_abs_diff(&c0) < 1e-3);
    }

    #[test]
    fn gemm_acc_accumulates() {
        let mut rng = Rng::seed_from_u64(5);
        let a = rand_mat(&mut rng, 8, 8);
        let b = rand_mat(&mut rng, 8, 8);
        let mut c = gemm(&a, &b);
        gemm_acc(&a, &b, &mut c);
        let mut c2 = gemm(&a, &b);
        c2.scale(2.0);
        assert!(c.max_abs_diff(&c2) < 1e-4);
    }

    #[test]
    fn threshold_is_tunable() {
        // Under the kernel lock: tests asserting bitwise equality between
        // dispatched GEMMs rely on the threshold holding still.
        let _serialize = kernels::force_lock();
        let before = parallel_flop_threshold();
        set_parallel_flop_threshold(123);
        assert_eq!(parallel_flop_threshold(), 123);
        set_parallel_flop_threshold(before);
    }
}
