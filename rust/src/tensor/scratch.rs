//! Per-thread reusable scratch buffers for the compute hot paths.
//!
//! Pool workers live for the process (or for a serving worker's
//! lifetime), so a buffer checked out here warms up to the largest size
//! its thread has seen and then stops allocating: steady-state batched
//! inference, packed-GEMM traffic, and warm training steps become
//! allocation-free (asserted by `tests/alloc_regression.rs`). The
//! consumers are the packed GEMM's panel buffers (`apack`/`bpack`), the
//! leaf-bucket activation tiles in `nn::fff`, the per-sample `a1`
//! buffer of `Fff::forward_infer`, `gemm_tn_acc`'s sparsity census, and
//! the per-row `t` scratch of the training backward's fused leaf pass.
//!
//! Checkout is stack-like and re-entrant: nested [`with_f32`] calls pop
//! distinct buffers, and each returns to the thread's free stack on
//! exit, so a bucket task that checks out an activation tile can still
//! run a packed GEMM that checks out panel buffers underneath it.
//!
//! Contents are **stale** on checkout (only capacity growth is
//! zero-filled, by `Vec::resize`): every caller fully overwrites the
//! slice it asked for, which the panel packers, gathers, and fused GEMM
//! epilogues all do by construction. Callers that accumulate (`C +=`)
//! must zero their slice first — `infer_grouped`'s activation tile does.
//!
//! If the closure panics the buffer is dropped rather than returned (a
//! later checkout simply allocates afresh), so a failing pool task can
//! never hand a poisoned buffer to an unrelated batch.

use std::cell::RefCell;

thread_local! {
    static F32_STACK: RefCell<Vec<Vec<f32>>> = const { RefCell::new(Vec::new()) };
    static U8_STACK: RefCell<Vec<Vec<u8>>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with a reusable thread-local scratch slice of exactly `len`
/// elements. Contents are unspecified (see module docs); the slice must
/// be fully overwritten (or zeroed) before being read.
pub fn with_f32<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    let mut buf = F32_STACK.with(|s| s.borrow_mut().pop()).unwrap_or_default();
    if buf.len() < len {
        buf.resize(len, 0.0);
    }
    let out = f(&mut buf[..len]);
    F32_STACK.with(|s| s.borrow_mut().push(buf));
    out
}

/// [`with_f32`] for biased-u8 quantized activation rows (the int8
/// serving path stores A-side bytes as `q + 127` — see
/// `kernels::quantize_row_q8_scalar`). Same stack-like checkout, same
/// staleness
/// contract: the quantize front fully overwrites every row it packs and
/// explicitly pads the `k` tail with the biased zero byte (127).
pub fn with_u8<R>(len: usize, f: impl FnOnce(&mut [u8]) -> R) -> R {
    let mut buf = U8_STACK.with(|s| s.borrow_mut().pop()).unwrap_or_default();
    if buf.len() < len {
        buf.resize(len, 0);
    }
    let out = f(&mut buf[..len]);
    U8_STACK.with(|s| s.borrow_mut().push(buf));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn returns_closure_result_and_exact_len() {
        let got = with_f32(17, |buf| {
            assert_eq!(buf.len(), 17);
            buf.fill(2.0);
            buf.iter().sum::<f32>()
        });
        assert_eq!(got, 34.0);
    }

    #[test]
    fn nested_checkouts_get_distinct_buffers() {
        with_f32(8, |outer| {
            outer.fill(1.0);
            with_f32(8, |inner| {
                inner.fill(2.0);
                assert_eq!(inner[0], 2.0);
            });
            // The inner checkout must not have aliased `outer`.
            assert!(outer.iter().all(|&v| v == 1.0));
        });
    }

    #[test]
    fn buffer_is_reused_across_checkouts() {
        // Warm a buffer, then check a second checkout of the same size
        // sees the retained (stale) contents — proof of reuse, and a
        // reminder that callers must overwrite.
        let marker = 1234.5f32;
        with_f32(33, |buf| buf.fill(marker));
        let stale = with_f32(33, |buf| buf[32]);
        assert_eq!(stale, marker);
    }

    #[test]
    fn growth_zero_fills_new_tail() {
        // A fresh thread-local stack (new thread) grows from empty: the
        // whole slice is zero-filled by the first checkout.
        std::thread::spawn(|| {
            with_f32(9, |buf| assert!(buf.iter().all(|&v| v == 0.0)));
        })
        .join()
        .unwrap();
    }

    #[test]
    fn u8_stack_mirrors_f32_semantics() {
        // Distinct nested buffers, reuse with stale contents, exact len.
        with_u8(8, |outer| {
            outer.fill(1);
            with_u8(8, |inner| inner.fill(2));
            assert!(outer.iter().all(|&v| v == 1));
        });
        with_u8(21, |buf| {
            assert_eq!(buf.len(), 21);
            buf.fill(200)
        });
        let stale = with_u8(21, |buf| buf[20]);
        assert_eq!(stale, 200);
    }

    #[test]
    fn panic_drops_buffer_without_poisoning() {
        let _ = std::panic::catch_unwind(|| {
            with_f32(4, |_| panic!("boom"));
        });
        // Subsequent checkouts still work.
        with_f32(4, |buf| buf.fill(1.0));
    }
}
