//! A reusable scoped thread pool for data-parallel compute (std-only).
//!
//! The pool runs *parallel regions*: [`ThreadPool::run`] takes a task count
//! and a borrowed `Fn(usize)` closure, and returns only after every task
//! index has been executed. Workers pull indices from a shared atomic
//! counter, so finishing early means stealing the remaining indices from
//! slower siblings — dynamic self-scheduling that load-balances the skewed
//! per-leaf batch sizes the FFF serving path produces (cf. the
//! load-balancing analysis in arXiv 2405.16836).
//!
//! Safety model: the closure is borrowed for the duration of `run` and
//! `run` blocks until all workers have retired the region, so the
//! lifetime-erased reference handed to the workers never outlives the
//! caller's borrow. Nested `run` calls (a pool task that itself calls
//! `run`, e.g. a leaf-bucket task invoking a parallel GEMM) execute inline
//! on the calling thread — no deadlock, no oversubscription.
//!
//! Sizing: the process-global pool defaults to `FFF_THREADS` or the
//! machine's available parallelism, and can be resized with
//! [`set_global_threads`]. Serving workers can instead pin a private pool
//! to their thread with [`set_current`] (the coordinator's `threads` knob).

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};

/// A raw pointer that may cross task closures. Holders must only derive
/// disjoint slices from it per task (e.g. row bands of one output buffer,
/// or row bands of the router's leaf-index buffer), which is what keeps
/// the aliasing sound. Defaults to `f32` — the element type of every GEMM
/// output — but is generic so integer-typed buffers can band-dispatch too.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr<T = f32>(pub(crate) *mut T);
// SAFETY: SendPtr is a plain address; sending it to another thread moves
// no data. Each holder derives only its own task's disjoint slice from
// it (the type's usage contract above), so no two threads ever form
// aliasing references through a copy.
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: sharing `&SendPtr` exposes only the raw address (Copy, no
// interior mutability); dereferencing is the holder's separately
// documented unsafe act, bound by the same disjoint-slice contract.
unsafe impl<T> Sync for SendPtr<T> {}

/// One parallel region, shared with the workers. Deliberately tiny and
/// allocation-free to clone: the region's task counter and panic flag
/// live in [`Shared`] (reset by `run` before each generation), so
/// dispatching a region performs **zero heap allocations** — serving
/// batches can fan out leaf buckets on every request without touching
/// the allocator (asserted by `tests/alloc_regression.rs`).
#[derive(Clone)]
struct Job {
    /// Lifetime-erased borrow of the caller's closure; sound because
    /// `run` does not return (or unwind) until `State::active` drops to
    /// zero.
    func: &'static (dyn Fn(usize) + Sync),
    n_tasks: usize,
}

struct State {
    job: Option<Job>,
    /// Bumped once per region; workers run each generation exactly once.
    generation: u64,
    /// Workers still executing the current region.
    active: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Workers wait here for a new generation (or shutdown).
    work_cv: Condvar,
    /// The submitting thread waits here for `active == 0`.
    done_cv: Condvar,
    /// Next task index of the current region (work stealing via
    /// fetch_add). Reset by `run` before the generation is published;
    /// safe to reuse across regions because the barrier guarantees every
    /// worker has retired the previous region first.
    next: AtomicUsize,
    /// Set when any task of the current region panicked; `run` re-panics
    /// after the barrier.
    panicked: std::sync::atomic::AtomicBool,
    /// First caught panic payload of the current region; `run` resumes
    /// the unwind with it after the barrier so the original message (and
    /// any typed payload) survives the pool crossing instead of being
    /// replaced by a generic string.
    panic_payload: Mutex<Option<Box<dyn std::any::Any + Send>>>,
}

/// The pool. Dropping it shuts the workers down and joins them.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Serializes parallel regions from concurrent submitters.
    submit: Mutex<()>,
    threads: usize,
}

thread_local! {
    /// True on pool worker threads and on any thread currently inside
    /// `run`; used to run nested regions inline.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
    /// Per-thread pool override (serving workers pin their own pool).
    static CURRENT: RefCell<Option<Arc<ThreadPool>>> = const { RefCell::new(None) };
}

impl ThreadPool {
    /// A pool where `run` executes across `threads` threads total: the
    /// submitting thread plus `threads - 1` workers. `threads <= 1` spawns
    /// nothing and `run` degenerates to a serial loop.
    pub fn new(threads: usize) -> ThreadPool {
        // Miri interprets every thread serially, so real workers only
        // multiply runtime without adding interleavings it can check;
        // under cfg(miri) every pool is the serial degenerate (the
        // documented shim — EXPERIMENTS.md §Analysis).
        let threads = if cfg!(miri) { 1 } else { threads.max(1) };
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, generation: 0, active: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
            next: AtomicUsize::new(0),
            panicked: std::sync::atomic::AtomicBool::new(false),
            panic_payload: Mutex::new(None),
        });
        let mut handles = Vec::with_capacity(threads - 1);
        for w in 0..threads - 1 {
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("fff-pool-{w}"))
                .spawn(move || worker_loop(&shared))
                .expect("spawn pool worker");
            handles.push(handle);
        }
        ThreadPool { shared, handles, submit: Mutex::new(()), threads }
    }

    /// Total threads a region runs across (submitter included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Execute `f(0), f(1), …, f(n_tasks - 1)`, distributed over the pool.
    ///
    /// Blocks until every task has run. Task order is unspecified; tasks
    /// must only touch disjoint data (or synchronize internally). Calls
    /// from inside a pool task run inline on the calling thread. A
    /// panicking task does not tear the region: the barrier still
    /// completes, then `run` re-panics on the submitting thread.
    pub fn run(&self, n_tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if n_tasks == 0 {
            return;
        }
        if self.handles.is_empty() || n_tasks == 1 || IN_POOL.with(|c| c.get()) {
            for t in 0..n_tasks {
                f(t);
            }
            return;
        }
        let _region = self.submit.lock().unwrap_or_else(|p| p.into_inner());
        // SAFETY: workers drop every reference to `func` before
        // decrementing `active`, and this function neither returns nor
        // unwinds until `active == 0` (task panics are caught and deferred
        // past the barrier), so the erased 'static borrow never outlives
        // `f`.
        let func: &'static (dyn Fn(usize) + Sync) = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        // Reset the region atomics BEFORE publishing the generation: the
        // mutex release below orders these stores ahead of any worker's
        // first read. Reuse is safe — the previous region's barrier
        // guaranteed every worker retired before `run` last returned.
        self.shared.next.store(0, Ordering::Relaxed);
        self.shared.panicked.store(false, Ordering::Relaxed);
        *self.shared.panic_payload.lock().unwrap_or_else(|p| p.into_inner()) = None;
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|p| p.into_inner());
            st.job = Some(Job { func, n_tasks });
            st.generation += 1;
            st.active = self.handles.len();
            self.shared.work_cv.notify_all();
        }
        // The submitting thread steals tasks too.
        IN_POOL.with(|c| c.set(true));
        loop {
            let t = self.shared.next.fetch_add(1, Ordering::Relaxed);
            if t >= n_tasks {
                break;
            }
            if let Err(payload) = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(t))) {
                store_panic(&self.shared, payload);
                break;
            }
        }
        IN_POOL.with(|c| c.set(false));
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|p| p.into_inner());
            while st.active > 0 {
                st = self.shared.done_cv.wait(st).unwrap_or_else(|p| p.into_inner());
            }
            st.job = None;
        }
        if self.shared.panicked.load(Ordering::Relaxed) {
            // Re-raise with the first caught payload so the caller sees
            // the task's own message; the pool itself is already back in
            // its idle state (barrier done, job cleared) and stays fully
            // usable for the next region.
            match self.shared.panic_payload.lock().unwrap_or_else(|p| p.into_inner()).take() {
                Some(payload) => std::panic::resume_unwind(payload),
                None => panic!("ThreadPool::run: a pool task panicked"),
            }
        }
    }
}

/// Record a caught task panic: first payload wins (later ones from
/// sibling tasks of the same region are dropped), flag set last so `run`
/// never re-raises before the payload is parked.
fn store_panic(shared: &Shared, payload: Box<dyn std::any::Any + Send>) {
    let mut slot = shared.panic_payload.lock().unwrap_or_else(|p| p.into_inner());
    if slot.is_none() {
        *slot = Some(payload);
    }
    drop(slot);
    shared.panicked.store(true, Ordering::Relaxed);
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap_or_else(|p| p.into_inner());
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    IN_POOL.with(|c| c.set(true));
    let mut seen_generation = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap_or_else(|p| p.into_inner());
            loop {
                if st.shutdown {
                    return;
                }
                if st.generation != seen_generation {
                    seen_generation = st.generation;
                    break st.job.clone().expect("generation bumped without a job");
                }
                st = shared.work_cv.wait(st).unwrap_or_else(|p| p.into_inner());
            }
        };
        loop {
            let t = shared.next.fetch_add(1, Ordering::Relaxed);
            if t >= job.n_tasks {
                break;
            }
            // Catch task panics so the region barrier always completes;
            // `run` resumes the unwind on the submitting thread.
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (job.func)(t)));
            if let Err(payload) = r {
                store_panic(shared, payload);
                break;
            }
        }
        // Drop the Job (and with it the lifetime-erased closure reference)
        // BEFORE decrementing `active`: once the last decrement lands,
        // `run` may return and invalidate the borrow.
        drop(job);
        let mut st = shared.state.lock().unwrap_or_else(|p| p.into_inner());
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// Default size for the global pool: `FFF_THREADS` or available cores.
/// Public so callers that resized the global pool (e.g. the bench thread
/// sweep) can restore the documented default without re-deriving it.
pub fn default_global_threads() -> usize {
    if let Ok(v) = std::env::var("FFF_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

fn global_cell() -> &'static RwLock<Arc<ThreadPool>> {
    static GLOBAL: OnceLock<RwLock<Arc<ThreadPool>>> = OnceLock::new();
    GLOBAL.get_or_init(|| RwLock::new(Arc::new(ThreadPool::new(default_global_threads()))))
}

/// The process-global pool (created on first use).
pub fn global() -> Arc<ThreadPool> {
    global_cell().read().unwrap_or_else(|p| p.into_inner()).clone()
}

/// Replace the global pool with an `n`-thread one (benches sweep 1/2/4/8).
/// In-flight regions on the old pool finish before it is dropped (`Arc`).
pub fn set_global_threads(n: usize) {
    let pool = Arc::new(ThreadPool::new(n));
    let old = {
        let mut guard = global_cell().write().unwrap_or_else(|p| p.into_inner());
        std::mem::replace(&mut *guard, pool)
    };
    // Joining the old pool's workers (if this was the last Arc) happens
    // outside the lock so `global()` callers never block on it.
    drop(old);
}

/// The pool compute kernels should dispatch on: the calling thread's
/// pinned pool if set ([`set_current`]), else the global pool.
pub fn current() -> Arc<ThreadPool> {
    if let Some(p) = CURRENT.with(|c| c.borrow().clone()) {
        return p;
    }
    global()
}

/// Pin (or clear) this thread's pool. Serving workers use this so each
/// worker's GEMM traffic runs on its own bounded pool (`threads` knob).
pub fn set_current(pool: Option<Arc<ThreadPool>>) {
    CURRENT.with(|c| *c.borrow_mut() = pool);
}

/// Run `f` with this thread's pool pinned to a fresh `threads`-wide pool,
/// restoring the previous pinning afterwards — also when `f` panics, so a
/// failing test cannot leak its pool into later tests on the same thread.
/// Test/bench helper: forces a thread count without touching the global
/// pool other threads share.
pub fn with_threads<R>(threads: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(Option<Arc<ThreadPool>>);
    impl Drop for Restore {
        fn drop(&mut self) {
            set_current(self.0.take());
        }
    }
    let _restore = Restore(CURRENT.with(|c| c.borrow().clone()));
    set_current(Some(Arc::new(ThreadPool::new(threads))));
    f()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        let pool = ThreadPool::new(4);
        for n_tasks in [1usize, 2, 3, 7, 64, 1000] {
            let hits: Vec<AtomicU64> = (0..n_tasks).map(|_| AtomicU64::new(0)).collect();
            pool.run(n_tasks, &|t| {
                hits[t].fetch_add(1, Ordering::Relaxed);
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n_tasks={n_tasks}: some task not run exactly once"
            );
        }
    }

    #[test]
    fn single_thread_pool_is_serial() {
        let pool = ThreadPool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicUsize::new(0);
        pool.run(10, &|t| {
            sum.fetch_add(t, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn pool_is_reusable_across_regions() {
        let pool = ThreadPool::new(3);
        let total = AtomicUsize::new(0);
        for _ in 0..50 {
            pool.run(17, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 50 * 17);
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = ThreadPool::new(2);
        let count = AtomicUsize::new(0);
        pool.run(4, &|_| {
            // A task dispatching its own region must not deadlock.
            pool.run(8, &|_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 32);
    }

    #[test]
    fn tasks_see_borrowed_data() {
        let pool = ThreadPool::new(4);
        let input: Vec<usize> = (0..100).collect();
        let out: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        pool.run(100, &|t| {
            out[t].store(input[t] * 2, Ordering::Relaxed);
        });
        for (i, o) in out.iter().enumerate() {
            assert_eq!(o.load(Ordering::Relaxed), 2 * i);
        }
    }

    #[test]
    fn concurrent_submitters_are_serialized() {
        let pool = Arc::new(ThreadPool::new(2));
        let total = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let pool = pool.clone();
            let total = total.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..20 {
                    pool.run(5, &|_| {
                        total.fetch_add(1, Ordering::Relaxed);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * 5);
    }

    #[test]
    fn task_panic_propagates_after_barrier_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(16, &|t| {
                if t == 3 {
                    panic!("boom");
                }
            });
        }));
        let payload = result.expect_err("panic should propagate out of run");
        // The task's own payload must survive the pool crossing, not a
        // generic "a pool task panicked" replacement.
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"boom"));
        // The pool must remain fully usable after a panicked region.
        let count = AtomicUsize::new(0);
        pool.run(8, &|_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn global_pool_survives_task_panic_bit_identically() {
        // The process-global pool — the one every kernel dispatch shares —
        // must not be wedged by a panicking region: the next region on the
        // same pool completes and produces the same bits as a serial run.
        let data: Vec<f32> = (0..512).map(|i| (i as f32 * 0.37).sin()).collect();
        let chunk = 32usize;
        let n_tasks = data.len() / chunk;
        let serial: Vec<f32> = (0..n_tasks)
            .map(|t| data[t * chunk..(t + 1) * chunk].iter().fold(0.0f32, |a, &v| a + v * v))
            .collect();
        let pool = global();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(n_tasks, &|t| {
                if t == 2 {
                    panic!("wedge attempt");
                }
            });
        }));
        let payload = result.expect_err("panic should propagate out of the global pool");
        assert_eq!(payload.downcast_ref::<&str>(), Some(&"wedge attempt"));
        // Next region on the same global pool: disjoint slots, fixed
        // per-slot arithmetic — must complete and match serial bitwise.
        let out: Vec<AtomicU64> = (0..n_tasks).map(|_| AtomicU64::new(0)).collect();
        pool.run(n_tasks, &|t| {
            let s = data[t * chunk..(t + 1) * chunk].iter().fold(0.0f32, |a, &v| a + v * v);
            out[t].store(s.to_bits() as u64, Ordering::Relaxed);
        });
        for (t, o) in out.iter().enumerate() {
            assert_eq!(
                o.load(Ordering::Relaxed) as u32,
                serial[t].to_bits(),
                "task {t} drifted after the panicked region"
            );
        }
    }

    #[test]
    fn set_current_overrides_global() {
        let pinned = Arc::new(ThreadPool::new(1));
        set_current(Some(pinned.clone()));
        assert_eq!(current().threads(), 1);
        set_current(None);
        // Back to the global pool (whatever its size is).
        assert!(current().threads() >= 1);
    }

    #[test]
    fn with_threads_scopes_and_restores_pinning() {
        // Under Miri every pool is serial (the cfg(miri) shim in `new`),
        // so expected widths clamp to 1 there.
        let w = |n: usize| if cfg!(miri) { 1 } else { n };
        let outer = Arc::new(ThreadPool::new(3));
        set_current(Some(outer.clone()));
        let inner = with_threads(2, || current().threads());
        assert_eq!(inner, w(2));
        assert_eq!(current().threads(), w(3), "previous pinning must be restored");
        set_current(None);
    }
}
