//! Training orchestration: the epoch loop implementing the paper's
//! measurement protocol — memorization accuracy `M_A`, generalization
//! accuracy `G_A`, and "epochs to train" (ETT) — with early stopping,
//! plateau LR-halving, and the FFF entropy monitor.

mod trainer;

pub use trainer::{
    build_model, ckpt_every_override, parse_ckpt_every_env, resolve_checkpoint_every,
    run_training, CheckpointPolicy, EpochRecord, EvalScratch, Outcome, Trainer,
};
