//! Training orchestration: the epoch loop implementing the paper's
//! measurement protocol — memorization accuracy `M_A`, generalization
//! accuracy `G_A`, and "epochs to train" (ETT) — with early stopping,
//! plateau LR-halving, and the FFF entropy monitor.

mod trainer;

pub use trainer::{build_model, run_training, EpochRecord, EvalScratch, Outcome, Trainer};
