//! The trainer.
//!
//! Paper protocol (Experiments section):
//! * the provided training set is split 9:1 into train/validation;
//! * `M_A` — train until training accuracy stops improving, report the
//!   best training-set accuracy (FFFs are always scored with `FORWARD_I`);
//! * `G_A` — use the parameters at the best validation accuracy, report
//!   their test-set accuracy;
//! * ETT — the number of epochs elapsed until the respective best score;
//! * early stopping after `patience` epochs without improvement on either
//!   monitor; optional LR halving on `lr_plateau`-epoch training-accuracy
//!   plateaus (the Table 2 recipe).
//!
//! Every forward/backward product runs on [`crate::tensor::gemm`]; batches
//! above its FLOP threshold (the Table 2 `batch_size = 4096` recipes in
//! particular) are dispatched across the [`crate::tensor::pool`] threads.

use crate::config::{ModelKind, OptimizerKind, TrainConfig};
use crate::data::{generate, BatchIter, Dataset, GenOptions};
use crate::nn::{
    checkpoint, loss::cross_entropy_into, Adam, Fff, FffConfig, Model, Moe, MoeConfig, Optimizer,
    Sgd,
};
use crate::rng::Rng;
use crate::tensor::Matrix;
use anyhow::Context;
use std::sync::OnceLock;

/// Checkpoint cadence and resume options for
/// [`Trainer::run_checkpointed`]. The default (no path) performs no
/// checkpoint I/O at all — [`Trainer::run`]'s behavior.
#[derive(Clone, Copy, Debug, Default)]
pub struct CheckpointPolicy<'p> {
    /// Save a full-resume checkpoint every `every` completed epochs
    /// (0 disables periodic saves).
    pub every: usize,
    /// Where the checkpoint lives; `None` disables checkpointing.
    pub path: Option<&'p std::path::Path>,
    /// Load `path` before training (if it exists) and continue from its
    /// cursor. A resumed run is bit-identical to an uninterrupted one:
    /// parameters, optimizer moments, RNG stream, and every protocol
    /// counter are restored exactly. A missing file is a fresh start.
    pub resume: bool,
}

/// Parse an `FFF_CKPT_EVERY` value: `None` on unset/empty/garbage
/// (garbage warned, never fatal — same contract as the
/// `FFF_DEADLINE_US` parser).
pub fn parse_ckpt_every_env(raw: Option<&str>) -> Option<usize> {
    let t = raw?.trim();
    if t.is_empty() {
        return None;
    }
    match t.parse::<usize>() {
        Ok(n) => Some(n),
        Err(_) => {
            eprintln!("fff: ignoring invalid FFF_CKPT_EVERY={t:?} (want a non-negative integer)");
            None
        }
    }
}

/// The `FFF_CKPT_EVERY` process override, read once. `Some(n)` forces a
/// checkpoint every `n` epochs regardless of config/flag (0 disables).
pub fn ckpt_every_override() -> Option<usize> {
    static ENV: OnceLock<Option<usize>> = OnceLock::new();
    *ENV.get_or_init(|| parse_ckpt_every_env(std::env::var("FFF_CKPT_EVERY").ok().as_deref()))
}

/// Layer the checkpoint cadence: preset default < `train.checkpoint_every`
/// config key < `--checkpoint-every` flag (the caller passes the
/// flag-resolved value in) < `FFF_CKPT_EVERY` env — the same precedence
/// chain as FFF_PRECISION / FFF_DEADLINE_US.
pub fn resolve_checkpoint_every(requested: usize) -> usize {
    ckpt_every_override().unwrap_or(requested)
}

/// Reusable buffers for the `FORWARD_I` scoring passes: `run` holds one
/// of these across **all** epochs, so the per-epoch train/val evaluations
/// (and the final test-set pass) reuse the same logits matrix and
/// prediction vector instead of allocating per batch per epoch — the
/// trainer-side counterpart of the serving path's
/// [`crate::nn::InferScratch`].
pub struct EvalScratch {
    logits: Matrix,
    preds: Vec<usize>,
}

impl EvalScratch {
    pub fn new() -> EvalScratch {
        EvalScratch { logits: Matrix::zeros(0, 0), preds: Vec::new() }
    }
}

impl Default for EvalScratch {
    fn default() -> EvalScratch {
        EvalScratch::new()
    }
}

/// Per-epoch log entry.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f32,
    pub aux_loss: f32,
    pub train_acc: f32,
    pub val_acc: f32,
    /// **Epoch-mean** (over batches) of the batch-mean node entropies per
    /// FFF layer — the paper's hardening monitor (Figures 5–6). Earlier
    /// revisions silently kept only the last batch's monitor; empty for
    /// models without FFF components.
    pub entropies: Vec<Vec<f32>>,
}

/// Result of a training run.
#[derive(Clone, Debug)]
pub struct Outcome {
    /// Best training-set accuracy (hard inference), the paper's `M_A`.
    pub memorization_accuracy: f32,
    /// Test accuracy of the best-validation snapshot, the paper's `G_A`.
    pub generalization_accuracy: f32,
    /// Epochs until `M_A` was reached.
    pub ett_memorization: usize,
    /// Epochs until the best validation accuracy was reached.
    pub ett_generalization: usize,
    pub epochs_run: usize,
    /// Mean wall-clock per epoch (training batches + scoring passes) —
    /// the throughput signal the Table 2 runs report.
    pub mean_epoch_ms: f64,
    pub history: Vec<EpochRecord>,
}

/// Build the model a [`TrainConfig`] describes.
pub fn build_model(
    cfg: &TrainConfig,
    dim_in: usize,
    dim_out: usize,
    rng: &mut Rng,
) -> Box<dyn Model> {
    match cfg.model {
        ModelKind::Ff => Box::new(crate::nn::Ff::new(rng, dim_in, cfg.width, dim_out)),
        ModelKind::Fff => {
            let mut fc = FffConfig::new(dim_in, dim_out, cfg.fff_depth(), cfg.leaf);
            fc.hardening = cfg.hardening;
            fc.transposition_p = cfg.transposition_p;
            fc.parallel_size = cfg.parallel_size;
            Box::new(Fff::new(rng, fc))
        }
        ModelKind::Moe => {
            let mut mc = MoeConfig::new(dim_in, dim_out, cfg.moe_experts(), cfg.leaf, cfg.k);
            mc.w_importance = cfg.w_importance;
            mc.w_load = cfg.w_load;
            Box::new(Moe::new(rng, mc))
        }
    }
}

/// Generic training driver over any [`Model`].
pub struct Trainer<'a> {
    pub cfg: &'a TrainConfig,
    pub train: Dataset,
    pub val: Dataset,
    pub test: Dataset,
}

impl<'a> Trainer<'a> {
    /// Materialize the config's dataset and apply the 9:1 split.
    pub fn from_config(cfg: &'a TrainConfig) -> Self {
        let (full_train, test) = generate(
            cfg.dataset,
            &GenOptions { train_n: cfg.train_n, test_n: cfg.test_n, seed: cfg.seed },
        );
        let (train, val) = full_train.split_train_val(cfg.seed);
        Trainer { cfg, train, val, test }
    }

    /// Run the full protocol on `model` (no checkpointing).
    pub fn run(&self, model: &mut dyn Model) -> Outcome {
        self.run_checkpointed(model, CheckpointPolicy::default())
            .expect("a checkpoint-free run performs no I/O and cannot fail")
    }

    /// [`Trainer::run`] with durable state: saves a full-resume
    /// checkpoint (parameters + optimizer + RNG + training cursor)
    /// every `policy.every` epochs, and — with `policy.resume` — picks
    /// an interrupted run back up bit-identically. Checkpoint I/O
    /// errors (full disk, bad path, corrupt resume file) surface as
    /// typed errors instead of panics.
    pub fn run_checkpointed(
        &self,
        model: &mut dyn Model,
        policy: CheckpointPolicy,
    ) -> anyhow::Result<Outcome> {
        let cfg = self.cfg;
        let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xABCD_EF01);
        let mut opt: Box<dyn Optimizer> = match cfg.optimizer {
            OptimizerKind::Sgd => Box::new(Sgd::new(cfg.lr)),
            OptimizerKind::Adam => Box::new(Adam::new(cfg.lr)),
        };

        let mut best_train_acc = f32::NEG_INFINITY;
        let mut best_val_acc = f32::NEG_INFINITY;
        let mut ett_mem = 0usize;
        let mut ett_gen = 0usize;
        let mut stale_epochs = 0usize;
        let mut plateau_epochs = 0usize;
        let mut history = Vec::new();
        let mut epochs_run = 0;
        let mut start_epoch = 1usize;
        // One scoring scratch for every evaluation this run performs.
        let mut eval_scratch = EvalScratch::new();
        // Step buffers retained for the whole run: batch inputs, logits,
        // loss gradient, and input gradient each live in exactly one
        // grow-only buffer, so warm training steps make zero heap
        // allocations end to end (tests/alloc_regression.rs pins the
        // model-side step; the batch refill is `next_batch_into`).
        let mut bx = Matrix::zeros(0, 0);
        let mut blabels: Vec<usize> = Vec::new();
        let mut logits = Matrix::zeros(0, 0);
        let mut dl = Matrix::zeros(0, 0);
        let mut dx = Matrix::zeros(0, 0);
        // One snapshot buffer reused across every improved-validation
        // epoch (Model::snapshot_into), instead of a fresh Vec each time.
        let mut best_val_snapshot: Vec<f32> = Vec::new();
        let mut have_snapshot = false;
        // Running entropy-monitor sums for the epoch mean.
        let mut ent_sums: Vec<Vec<f32>> = Vec::new();
        let mut epoch_ms_total = 0.0f64;

        if policy.resume {
            if let Some(path) = policy.path.filter(|p| p.exists()) {
                let ckpt = checkpoint::read(path)?;
                let cursor = ckpt.cursor.clone().with_context(|| {
                    format!("{path:?}: checkpoint has no training cursor (not a resumable run)")
                })?;
                checkpoint::apply(model, &ckpt).with_context(|| format!("{path:?}"))?;
                let blob = ckpt
                    .optimizer
                    .as_ref()
                    .with_context(|| format!("{path:?}: checkpoint has no optimizer state"))?;
                opt.load_state(blob)
                    .map_err(|e| anyhow::anyhow!("{path:?}: optimizer state: {e}"))?;
                let state = ckpt
                    .rng
                    .with_context(|| format!("{path:?}: checkpoint has no RNG state"))?;
                rng = Rng::from_state(state)
                    .with_context(|| format!("{path:?}: invalid RNG state"))?;
                best_train_acc = cursor.best_train_acc;
                best_val_acc = cursor.best_val_acc;
                ett_mem = cursor.ett_memorization as usize;
                ett_gen = cursor.ett_generalization as usize;
                stale_epochs = cursor.stale_epochs as usize;
                plateau_epochs = cursor.plateau_epochs as usize;
                epoch_ms_total = cursor.epoch_ms_total;
                if let Some(snap) = cursor.best_val_snapshot {
                    best_val_snapshot = snap;
                    have_snapshot = true;
                }
                history = cursor
                    .history
                    .iter()
                    .map(|h| EpochRecord {
                        epoch: h.epoch as usize,
                        train_loss: h.train_loss,
                        aux_loss: h.aux_loss,
                        train_acc: h.train_acc,
                        val_acc: h.val_acc,
                        entropies: h.entropies.clone(),
                    })
                    .collect();
                epochs_run = cursor.epoch as usize;
                start_epoch = cursor.epoch as usize + 1;
            }
        }

        for epoch in start_epoch..=cfg.max_epochs {
            epochs_run = epoch;
            let epoch_start = std::time::Instant::now();
            let mut epoch_loss = 0.0;
            let mut epoch_aux = 0.0;
            let mut batches = 0usize;
            // Keep the sums' group structure across epochs (zeroed, not
            // cleared) so the accumulation stays allocation-free.
            for sum in ent_sums.iter_mut() {
                sum.iter_mut().for_each(|s| *s = 0.0);
            }
            let mut it = BatchIter::shuffled(&self.train, cfg.batch_size, &mut rng);
            while it.next_batch_into(&mut bx, &mut blabels) {
                model.forward_train_into(&bx, &mut rng, &mut logits);
                let loss = cross_entropy_into(&logits, &blabels, &mut dl);
                model.zero_grad();
                model.backward_into(&dl, &mut dx);
                opt.step(model);
                epoch_loss += loss;
                epoch_aux += model.aux_loss();
                // Accumulate the hardening monitor: the epoch record is
                // the mean over batches, not the last batch's snapshot.
                model.accumulate_entropies(&mut ent_sums);
                batches += 1;
            }

            let train_acc = self.eval_infer_with(model, &self.train, &mut eval_scratch);
            let val_acc = self.eval_infer_with(model, &self.val, &mut eval_scratch);

            let improved_train = train_acc > best_train_acc + 1e-6;
            if improved_train {
                best_train_acc = train_acc;
                ett_mem = epoch;
                plateau_epochs = 0;
            } else {
                plateau_epochs += 1;
            }
            let improved_val = val_acc > best_val_acc + 1e-6;
            if improved_val {
                best_val_acc = val_acc;
                ett_gen = epoch;
                model.snapshot_into(&mut best_val_snapshot);
                have_snapshot = true;
            }
            if improved_train || improved_val {
                stale_epochs = 0;
            } else {
                stale_epochs += 1;
            }

            let inv_batches = 1.0 / batches.max(1) as f32;
            let entropies: Vec<Vec<f32>> = ent_sums
                .iter()
                .map(|sum| sum.iter().map(|&s| s * inv_batches).collect())
                .collect();
            epoch_ms_total += epoch_start.elapsed().as_secs_f64() * 1e3;
            history.push(EpochRecord {
                epoch,
                train_loss: epoch_loss * inv_batches,
                aux_loss: epoch_aux * inv_batches,
                train_acc,
                val_acc,
                entropies,
            });

            if cfg.lr_plateau > 0 && plateau_epochs >= cfg.lr_plateau {
                opt.set_lr(opt.lr() / 2.0);
                plateau_epochs = 0;
            }
            if cfg.patience > 0 && stale_epochs >= cfg.patience {
                break;
            }
            // Memorization reached its ceiling — nothing left to learn.
            if best_train_acc >= 1.0 - 1e-6 && best_val_acc >= 1.0 - 1e-6 {
                break;
            }
            // Periodic resume checkpoint — placed *after* the stop
            // checks, so a checkpoint is only ever cut at a point the
            // run would continue from; resume can therefore re-enter
            // the loop unconditionally at `cursor.epoch + 1` and any
            // stop condition replays deterministically.
            if policy.every > 0 && epoch % policy.every == 0 {
                if let Some(path) = policy.path {
                    let mut ckpt = checkpoint::capture(model);
                    let mut blob = Vec::new();
                    opt.save_state(&mut blob);
                    ckpt.optimizer = Some(blob);
                    ckpt.rng = Some(rng.state());
                    ckpt.cursor = Some(checkpoint::TrainCursor {
                        epoch: epoch as u64,
                        batch: 0,
                        best_train_acc,
                        best_val_acc,
                        ett_memorization: ett_mem as u64,
                        ett_generalization: ett_gen as u64,
                        stale_epochs: stale_epochs as u64,
                        plateau_epochs: plateau_epochs as u64,
                        epoch_ms_total,
                        best_val_snapshot: if have_snapshot {
                            Some(best_val_snapshot.clone())
                        } else {
                            None
                        },
                        history: history
                            .iter()
                            .map(|h| checkpoint::CursorEpoch {
                                epoch: h.epoch as u64,
                                train_loss: h.train_loss,
                                aux_loss: h.aux_loss,
                                train_acc: h.train_acc,
                                val_acc: h.val_acc,
                                entropies: h.entropies.clone(),
                            })
                            .collect(),
                    });
                    checkpoint::save_checkpoint(&ckpt, path)
                        .with_context(|| format!("periodic checkpoint at epoch {epoch}"))?;
                }
            }
        }

        // G_A: restore the best-validation snapshot, evaluate on test.
        let generalization_accuracy = if have_snapshot {
            let current = model.snapshot();
            model.restore(&best_val_snapshot);
            let acc = self.eval_infer_with(model, &self.test, &mut eval_scratch);
            model.restore(&current);
            acc
        } else {
            self.eval_infer_with(model, &self.test, &mut eval_scratch)
        };

        Ok(Outcome {
            memorization_accuracy: best_train_acc.max(0.0),
            generalization_accuracy,
            ett_memorization: ett_mem,
            ett_generalization: ett_gen,
            epochs_run,
            mean_epoch_ms: epoch_ms_total / epochs_run.max(1) as f64,
            history,
        })
    }

    /// Evaluate hard-inference accuracy on a dataset, in batches.
    pub fn eval_infer(&self, model: &dyn Model, data: &Dataset) -> f32 {
        self.eval_infer_with(model, data, &mut EvalScratch::new())
    }

    /// [`Trainer::eval_infer`] with caller-retained scoring buffers —
    /// what `run` uses so every epoch's `FORWARD_I` passes share one
    /// scratch instead of allocating logits/predictions per batch.
    pub fn eval_infer_with(
        &self,
        model: &dyn Model,
        data: &Dataset,
        scratch: &mut EvalScratch,
    ) -> f32 {
        let mut hits = 0usize;
        for (x, labels) in BatchIter::sequential(data, 512) {
            model.forward_infer_into(&x, &mut scratch.logits);
            crate::tensor::argmax_rows_into(&scratch.logits, &mut scratch.preds);
            hits += scratch.preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
        }
        hits as f32 / data.len().max(1) as f32
    }
}

/// One-call convenience: build dataset + model from a config and train.
pub fn run_training(cfg: &TrainConfig) -> Outcome {
    let trainer = Trainer::from_config(cfg);
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut model = build_model(cfg, trainer.train.dim(), trainer.train.num_classes, &mut rng);
    trainer.run(model.as_mut())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DatasetKind;

    fn quick_cfg(model: ModelKind) -> TrainConfig {
        let mut c = TrainConfig::table1(DatasetKind::Usps, model, 32, 8, 0);
        c.train_n = 600;
        c.test_n = 200;
        c.max_epochs = 30;
        c.patience = 10;
        c
    }

    #[test]
    fn ff_trains_to_reasonable_accuracy() {
        let out = run_training(&quick_cfg(ModelKind::Ff));
        assert!(out.memorization_accuracy > 0.7, "M_A={}", out.memorization_accuracy);
        assert!(out.generalization_accuracy > 0.6, "G_A={}", out.generalization_accuracy);
        assert!(out.ett_memorization >= 1);
        assert!(!out.history.is_empty());
    }

    #[test]
    fn fff_trains_and_hard_inference_works() {
        let out = run_training(&quick_cfg(ModelKind::Fff));
        assert!(out.memorization_accuracy > 0.6, "M_A={}", out.memorization_accuracy);
        assert!(out.generalization_accuracy > 0.5, "G_A={}", out.generalization_accuracy);
    }

    #[test]
    fn history_is_monotone_in_epochs() {
        let out = run_training(&quick_cfg(ModelKind::Ff));
        for (i, rec) in out.history.iter().enumerate() {
            assert_eq!(rec.epoch, i + 1);
        }
        assert!(out.epochs_run <= 30);
    }

    #[test]
    fn early_stopping_respects_patience() {
        let mut cfg = quick_cfg(ModelKind::Ff);
        cfg.patience = 3;
        cfg.max_epochs = 100;
        let out = run_training(&cfg);
        // Must stop well before max_epochs on this easy task.
        assert!(out.epochs_run < 100);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = quick_cfg(ModelKind::Fff);
        let a = run_training(&cfg);
        let b = run_training(&cfg);
        assert_eq!(a.memorization_accuracy, b.memorization_accuracy);
        assert_eq!(a.generalization_accuracy, b.generalization_accuracy);
        assert_eq!(a.epochs_run, b.epochs_run);
    }

    #[test]
    fn mean_epoch_ms_is_populated() {
        let mut cfg = quick_cfg(ModelKind::Ff);
        cfg.max_epochs = 3;
        cfg.patience = 0;
        let out = run_training(&cfg);
        assert!(out.mean_epoch_ms > 0.0, "mean_epoch_ms = {}", out.mean_epoch_ms);
    }

    #[test]
    fn parse_ckpt_every_env_contract() {
        assert_eq!(parse_ckpt_every_env(None), None);
        assert_eq!(parse_ckpt_every_env(Some("")), None);
        assert_eq!(parse_ckpt_every_env(Some("  ")), None);
        assert_eq!(parse_ckpt_every_env(Some("5")), Some(5));
        assert_eq!(parse_ckpt_every_env(Some(" 12 ")), Some(12));
        assert_eq!(parse_ckpt_every_env(Some("0")), Some(0), "0 explicitly disables");
        assert_eq!(parse_ckpt_every_env(Some("-3")), None, "garbage warns, never fatal");
        assert_eq!(parse_ckpt_every_env(Some("abc")), None);
    }

    #[test]
    fn resumed_run_is_bit_identical_to_uninterrupted() {
        let mut cfg = quick_cfg(ModelKind::Ff);
        cfg.max_epochs = 6;
        cfg.patience = 0;
        let path = std::env::temp_dir()
            .join(format!("fff-trainer-resume-{}.ckpt", std::process::id()));
        std::fs::remove_file(&path).ok();

        // Control: 6 epochs straight through.
        let trainer = Trainer::from_config(&cfg);
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let mut control =
            build_model(&cfg, trainer.train.dim(), trainer.train.num_classes, &mut rng);
        let control_out = trainer.run(control.as_mut());

        // Interrupted: stop after 3 epochs (checkpointing every epoch),
        // then resume in a *fresh process-equivalent* — new model, new
        // trainer — and run to completion.
        let mut cfg_cut = cfg.clone();
        cfg_cut.max_epochs = 3;
        let trainer_cut = Trainer::from_config(&cfg_cut);
        let mut rng2 = Rng::seed_from_u64(cfg.seed);
        let mut victim =
            build_model(&cfg, trainer_cut.train.dim(), trainer_cut.train.num_classes, &mut rng2);
        trainer_cut
            .run_checkpointed(
                victim.as_mut(),
                CheckpointPolicy { every: 1, path: Some(&path), resume: false },
            )
            .unwrap();

        let trainer_resume = Trainer::from_config(&cfg);
        let mut rng3 = Rng::seed_from_u64(cfg.seed);
        let mut resumed = build_model(
            &cfg,
            trainer_resume.train.dim(),
            trainer_resume.train.num_classes,
            &mut rng3,
        );
        let resumed_out = trainer_resume
            .run_checkpointed(
                resumed.as_mut(),
                CheckpointPolicy { every: 1, path: Some(&path), resume: true },
            )
            .unwrap();

        assert_eq!(control.snapshot(), resumed.snapshot(), "weights must be bit-identical");
        assert_eq!(control_out.memorization_accuracy, resumed_out.memorization_accuracy);
        assert_eq!(control_out.generalization_accuracy, resumed_out.generalization_accuracy);
        assert_eq!(control_out.ett_memorization, resumed_out.ett_memorization);
        assert_eq!(control_out.ett_generalization, resumed_out.ett_generalization);
        assert_eq!(control_out.epochs_run, resumed_out.epochs_run);
        assert_eq!(control_out.history.len(), resumed_out.history.len());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resume_from_params_only_checkpoint_is_refused() {
        let cfg = quick_cfg(ModelKind::Ff);
        let trainer = Trainer::from_config(&cfg);
        let mut rng = Rng::seed_from_u64(cfg.seed);
        let mut model = build_model(&cfg, trainer.train.dim(), trainer.train.num_classes, &mut rng);
        let path = std::env::temp_dir()
            .join(format!("fff-trainer-paramsonly-{}.ckpt", std::process::id()));
        checkpoint::save(model.as_mut(), &path).unwrap();
        let err = trainer
            .run_checkpointed(
                model.as_mut(),
                CheckpointPolicy { every: 0, path: Some(&path), resume: true },
            )
            .unwrap_err();
        assert!(format!("{err:#}").contains("cursor"), "{err:#}");
        std::fs::remove_file(&path).ok();
    }

    /// A model whose entropy report is scripted per training batch —
    /// batch `k` (1-based) reports `[[k]]` — so the epoch record's
    /// monitor is checkable exactly.
    struct ScriptedEntropy {
        calls: usize,
        classes: usize,
    }

    impl crate::nn::Model for ScriptedEntropy {
        fn forward_train(&mut self, x: &Matrix, _rng: &mut crate::rng::Rng) -> Matrix {
            self.calls += 1;
            Matrix::zeros(x.rows(), self.classes)
        }

        fn backward(&mut self, _d_logits: &Matrix) -> Matrix {
            Matrix::zeros(1, 1)
        }

        fn forward_infer(&self, x: &Matrix) -> Matrix {
            Matrix::zeros(x.rows(), self.classes)
        }

        fn visit_params(&mut self, _f: &mut crate::nn::ParamVisitor) {}

        fn entropy_report(&self) -> Vec<Vec<f32>> {
            vec![vec![self.calls as f32]]
        }
    }

    #[test]
    fn epoch_record_entropies_are_the_mean_over_batches() {
        // Regression for the last-batch-only monitor bug: with batch
        // reports 1, 2, …, k the recorded epoch monitor must be the mean
        // (k + 1) / 2, not the final k.
        let mut cfg = quick_cfg(ModelKind::Ff);
        cfg.max_epochs = 1;
        cfg.patience = 0;
        cfg.batch_size = 32;
        let trainer = Trainer::from_config(&cfg);
        let mut model =
            ScriptedEntropy { calls: 0, classes: trainer.train.num_classes };
        let out = trainer.run(&mut model);
        let k = trainer.train.len().div_ceil(32);
        assert!(k > 1, "need multiple batches for the regression to bite (k = {k})");
        let want = (1..=k).sum::<usize>() as f32 / k as f32;
        assert_eq!(out.history.len(), 1);
        assert_eq!(out.history[0].entropies.len(), 1);
        let got = out.history[0].entropies[0][0];
        assert!(
            (got - want).abs() < 1e-5,
            "epoch monitor {got} is not the batch mean {want} (k = {k})"
        );
        assert_ne!(got, k as f32, "monitor must not be the last batch's value");
    }
}
