//! `fff analyze` — std-only static analysis for the SIMD/pool core.
//!
//! Three rule families, all hard errors in CI:
//!
//! 1. [`unsafe_audit`] — `unsafe` containment (allowlisted modules
//!    only), `// SAFETY:` documentation on every site, and the
//!    crate-wide `#![deny(unsafe_op_in_unsafe_fn)]` lint.
//! 2. [`parity`] — every SIMD kernel registered in the dispatch tables
//!    (`KernelTable`, `I8Kernels`) has a scalar replica and a test that
//!    references it by name.
//! 3. [`determinism`] — no float accumulation over `HashMap`/`HashSet`
//!    iteration order; no pool reductions whose task count derives from
//!    the thread count.
//!
//! The scanner ([`source`]) is lexical, not syntactic: it blanks
//! comments and string contents so rules cannot be fooled by literals,
//! then pattern-matches on the code view. That makes the analyzer
//! cheap, dependency-free, and — because the rules are narrow — low on
//! false positives; the repo tree must come back clean
//! (`tests/analyze_repo.rs` pins that).

pub mod determinism;
pub mod parity;
pub mod source;
pub mod unsafe_audit;

use source::SourceFile;
use std::path::{Path, PathBuf};

/// One rule violation: rule id, repo-relative file, 1-based line,
/// human-oriented message.
#[derive(Debug, Clone, PartialEq)]
pub struct Finding {
    pub rule: String,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(rule: &str, file: &str, line: usize, message: &str) -> Finding {
        Finding {
            rule: rule.to_string(),
            file: file.to_string(),
            line,
            message: message.to_string(),
        }
    }
}

impl std::fmt::Display for Finding {
    fn fmt(&self, out: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(out, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// Run every rule family over an in-memory file set (fixtures or a
/// loaded tree). Findings come back sorted by file, line, rule.
pub fn analyze_sources(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    findings.extend(unsafe_audit::check(files));
    findings.extend(parity::check(files));
    findings.extend(determinism::check(files));
    findings.sort_by(|a, b| {
        (&a.file, a.line, &a.rule).cmp(&(&b.file, b.line, &b.rule))
    });
    findings
}

/// Load `src/`, `tests/`, and `benches/` `.rs` files under the crate
/// root and analyze them. Accepts either the crate root itself or a
/// repo root with a `rust/` crate inside.
pub fn analyze_tree(root: &Path) -> std::io::Result<(Vec<Finding>, usize)> {
    let crate_root = resolve_crate_root(root)?;
    let mut files = Vec::new();
    for dir in ["src", "tests", "benches"] {
        let base = crate_root.join(dir);
        if base.is_dir() {
            collect_rs(&base, &mut files)?;
        }
    }
    // Deterministic order (directory iteration order is OS-dependent).
    files.sort();
    let sources: Vec<SourceFile> = files
        .iter()
        .map(|p| {
            let text = std::fs::read_to_string(p)?;
            let rel = p
                .strip_prefix(&crate_root)
                .unwrap_or(p)
                .to_string_lossy()
                .replace('\\', "/");
            Ok(SourceFile::from_text(&rel, &text))
        })
        .collect::<std::io::Result<_>>()?;
    Ok((analyze_sources(&sources), sources.len()))
}

fn resolve_crate_root(root: &Path) -> std::io::Result<PathBuf> {
    if root.join("src").is_dir() {
        return Ok(root.to_path_buf());
    }
    if root.join("rust").join("src").is_dir() {
        return Ok(root.join("rust"));
    }
    Err(std::io::Error::new(
        std::io::ErrorKind::NotFound,
        format!("no crate root (src/) at or under {}", root.display()),
    ))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// CLI entry for `fff analyze [--root PATH]`. Prints findings and a
/// summary; returns the process exit code (0 clean, 1 findings, 2
/// usage/io error).
pub fn run_cli(root: Option<&str>) -> i32 {
    let root = PathBuf::from(root.unwrap_or("."));
    match analyze_tree(&root) {
        Ok((findings, scanned)) => {
            for f in &findings {
                println!("{f}");
            }
            if findings.is_empty() {
                println!("fff analyze: clean ({scanned} files scanned)");
                0
            } else {
                println!(
                    "fff analyze: {} finding(s) in {scanned} files — fix or \
                     extend the allowlist (see EXPERIMENTS.md §Analysis)",
                    findings.len()
                );
                1
            }
        }
        Err(e) => {
            eprintln!("fff analyze: {e}");
            2
        }
    }
}

// ------------------------------------------------------------------------
// Fixture self-tests: every rule must fire on a seeded violation and
// stay silent on the clean twin.
// ------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    fn files(specs: &[(&str, &str)]) -> Vec<SourceFile> {
        specs.iter().map(|(p, t)| SourceFile::from_text(p, t)).collect()
    }

    fn rules(findings: &[Finding]) -> Vec<&str> {
        findings.iter().map(|f| f.rule.as_str()).collect()
    }

    #[test]
    fn undocumented_unsafe_fires_and_documented_is_clean() {
        let dirty = files(&[(
            "src/tensor/pool.rs",
            "fn f(p: *mut f32) {\n    unsafe { *p = 1.0; }\n}\n",
        )]);
        assert_eq!(rules(&analyze_sources(&dirty)), ["undocumented-unsafe"]);

        let clean = files(&[(
            "src/tensor/pool.rs",
            "fn f(p: *mut f32) {\n    // SAFETY: p is valid per caller contract.\n    \
             unsafe { *p = 1.0; }\n}\n",
        )]);
        assert!(analyze_sources(&clean).is_empty());
    }

    #[test]
    fn safety_comment_above_wrapped_statement_is_accepted() {
        let clean = files(&[(
            "src/tensor/pool.rs",
            "fn f(p: *const f32) -> f32 {\n    // SAFETY: p valid for reads.\n    \
             let v =\n        unsafe { *p };\n    v\n}\n",
        )]);
        assert!(analyze_sources(&clean).is_empty());
    }

    #[test]
    fn unsafe_fn_accepts_safety_doc_section() {
        let clean = files(&[(
            "src/tensor/pool.rs",
            "/// # Safety\n/// `p` must be valid for writes.\nunsafe fn poke(p: *mut f32) {\n    \
             // SAFETY: per the fn contract above.\n    unsafe { *p = 0.0; }\n}\n",
        )]);
        assert!(analyze_sources(&clean).is_empty());
    }

    #[test]
    fn unsafe_outside_allowlist_fires() {
        let dirty = files(&[(
            "src/data/loader.rs",
            "fn f(p: *mut u8) {\n    // SAFETY: documented but still misplaced.\n    \
             unsafe { *p = 0; }\n}\n",
        )]);
        assert_eq!(rules(&analyze_sources(&dirty)), ["unsafe-outside-allowlist"]);
    }

    #[test]
    fn unsafe_fn_pointer_types_are_exempt() {
        let clean = files(&[(
            "src/runtime/exec.rs",
            "type Kernel = unsafe fn(*const f32, usize);\nstruct T { k: unsafe fn(usize) }\n",
        )]);
        assert!(analyze_sources(&clean).is_empty());
    }

    #[test]
    fn missing_crate_lint_fires() {
        let dirty = files(&[("src/lib.rs", "pub mod tensor;\n")]);
        assert_eq!(rules(&analyze_sources(&dirty)), ["missing-unsafe-op-lint"]);

        let clean =
            files(&[("src/lib.rs", "#![deny(unsafe_op_in_unsafe_fn)]\npub mod tensor;\n")]);
        assert!(analyze_sources(&clean).is_empty());
    }

    /// A minimal kernels.rs fixture: one dispatch table registering one
    /// SIMD entry, with the replica and test reference controllable.
    fn kernels_fixture(with_replica: bool, with_test: bool) -> Vec<SourceFile> {
        let mut kernels = String::from(
            "pub struct KernelTable { pub micro_4x8: fn(usize) }\n\
             fn micro_4x8_fast_entry(_: usize) {}\n",
        );
        if with_replica {
            kernels.push_str("fn micro_4x8_ref(_: usize) {}\nfn micro_4x8_portable(_: usize) {}\n");
        }
        kernels.push_str(
            "pub fn detect() -> KernelTable {\n    KernelTable { micro_4x8: micro_4x8_fast_entry }\n}\n",
        );
        let test = if with_test {
            "#[test]\nfn fast_matches_ref() { crate::k::micro_4x8_fast_entry(1); }\n"
        } else {
            "#[test]\nfn unrelated() {}\n"
        };
        files(&[
            ("src/tensor/kernels.rs", kernels.as_str()),
            ("tests/golden_vectors.rs", test),
        ])
    }

    #[test]
    fn kernel_without_replica_fires() {
        let got = analyze_sources(&kernels_fixture(false, true));
        assert!(rules(&got).contains(&"kernel-missing-scalar-replica"), "{got:?}");
    }

    #[test]
    fn kernel_without_test_reference_fires() {
        let got = analyze_sources(&kernels_fixture(true, false));
        assert_eq!(rules(&got), ["kernel-missing-test-reference"]);
    }

    #[test]
    fn kernel_with_replica_and_test_is_clean() {
        assert!(analyze_sources(&kernels_fixture(true, true)).is_empty());
    }

    #[test]
    fn hashmap_order_float_accumulation_fires() {
        let dirty = files(&[(
            "src/train/stats.rs",
            "use std::collections::HashMap;\nfn f() -> f32 {\n    \
             let mut m: HashMap<u32, f32> = HashMap::new();\n    m.insert(1, 2.0);\n    \
             let mut acc = 0.0f32;\n    for (_, v) in &m {\n        acc += v;\n    }\n    \
             acc\n}\n",
        )]);
        assert_eq!(rules(&analyze_sources(&dirty)), ["hashmap-order-float-accumulation"]);
    }

    #[test]
    fn vec_accumulation_is_clean() {
        let clean = files(&[(
            "src/train/stats.rs",
            "fn f(xs: &[f32]) -> f32 {\n    let mut acc = 0.0f32;\n    \
             for v in xs {\n        acc += v;\n    }\n    acc\n}\n",
        )]);
        assert!(analyze_sources(&clean).is_empty());
    }

    #[test]
    fn hashmap_iteration_without_accumulation_is_clean() {
        let clean = files(&[(
            "src/train/stats.rs",
            "use std::collections::HashMap;\nfn f() {\n    \
             let m: HashMap<u32, f32> = HashMap::new();\n    \
             for (k, _) in &m {\n        println!(\"{k}\");\n    }\n}\n",
        )]);
        assert!(analyze_sources(&clean).is_empty());
    }

    #[test]
    fn thread_derived_pool_reduction_fires() {
        let dirty = files(&[(
            "src/train/engine.rs",
            "fn f(pool: &Pool, acc: &mut [f32]) {\n    \
             let bands = pool.threads() * 4;\n    \
             let n = bands + 1;\n    \
             pool.run(n, &|t| {\n        acc[t] += 1.0;\n    });\n}\n",
        )]);
        assert_eq!(rules(&analyze_sources(&dirty)), ["pool-reduction-thread-dependent"]);
    }

    #[test]
    fn batch_derived_pool_reduction_is_clean() {
        let clean = files(&[(
            "src/train/engine.rs",
            "fn f(pool: &Pool, rows: usize, acc: &mut [f32]) {\n    \
             let n = rows.div_ceil(128);\n    \
             pool.run(n, &|t| {\n        acc[t] += 1.0;\n    });\n}\n",
        )]);
        assert!(analyze_sources(&clean).is_empty());
    }

    #[test]
    fn thread_derived_tiling_without_accumulation_is_clean() {
        let clean = files(&[(
            "src/train/engine.rs",
            "fn f(pool: &Pool, out: &mut [f32]) {\n    \
             let n = pool.threads() * 4;\n    \
             pool.run(n, &|t| {\n        out[t] = t as f32;\n    });\n}\n",
        )]);
        assert!(analyze_sources(&clean).is_empty());
    }

    #[test]
    fn string_literals_do_not_fool_rules() {
        let clean = files(&[(
            "src/cli.rs",
            "fn f() {\n    let msg = \"unsafe { } for x in map += .threads()\";\n    \
             println!(\"{msg}\");\n}\n",
        )]);
        assert!(analyze_sources(&clean).is_empty());
    }

    #[test]
    fn findings_are_sorted_and_display_cleanly() {
        let dirty = files(&[
            ("src/b.rs", "fn f(p: *mut u8) {\n    unsafe { *p = 0; }\n}\n"),
            ("src/a.rs", "fn f(p: *mut u8) {\n    unsafe { *p = 0; }\n}\n"),
        ]);
        let got = analyze_sources(&dirty);
        assert_eq!(got.len(), 4); // allowlist + undocumented, per file
        assert!(got[0].file <= got[2].file);
        let shown = format!("{}", got[0]);
        assert!(shown.contains("src/a.rs:2:"), "{shown}");
    }
}
