//! Rule family 2: kernel parity.
//!
//! The bit-identity guarantee rests on every SIMD kernel having (a) a
//! scalar replica that states the numerics in plain Rust and (b) a test
//! that exercises the kernel *by name* against golden vectors or the
//! forced-kernel matrix. This rule machine-checks both by parsing the
//! dispatch-table registrations out of `src/tensor/kernels.rs`:
//!
//! * every `KernelTable { .. }` literal's `micro_4x8` / `micro_4x8_epi` /
//!   `routing_dot` fields, and
//! * every `I8Kernels { .. }` literal's `quant_row` / `tile` / `tile_x2`
//!   / `tile_leaf` fields,
//!
//! then requiring, per registered entry: the field's scalar replica
//! (see [`replicas_for`]) is defined in the kernels module, and the
//! entry's base name (minus a trailing `_entry`) appears in the test
//! corpus — `tests/*.rs` (golden vectors, quant goldens, the
//! `check_kernels` property call sites) plus the `#[cfg(test)]` regions
//! of `src/` files.

use super::source::{contains_ident, SourceFile};
use super::Finding;

const RULE_REPLICA: &str = "kernel-missing-scalar-replica";
const RULE_TEST_REF: &str = "kernel-missing-test-reference";

/// Dispatch-table fields the rule audits, per table type.
const TABLE_FIELDS: &[&str] = &["micro_4x8", "micro_4x8_epi", "routing_dot"];
const I8_FIELDS: &[&str] = &["quant_row", "tile", "tile_x2", "tile_leaf"];

/// The scalar replicas each field's registered kernels must match.
/// At least one replica per field must be defined in the kernels file.
fn replicas_for(field: &str) -> &'static [&'static str] {
    match field {
        "micro_4x8" => &["micro_4x8_ref", "micro_4x8_portable"],
        "micro_4x8_epi" => &["micro_4x8_ref_epi", "micro_4x8_portable_epi"],
        "routing_dot" => &["routing_dot_scalar"],
        "quant_row" => &["quantize_row_q8_scalar"],
        "tile" | "tile_x2" | "tile_leaf" => &["tile_i8_scalar"],
        _ => &[],
    }
}

/// A registered dispatch entry: table field + function identifier.
struct Registration {
    field: String,
    func: String,
    line: usize,
}

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let Some(kernels) = files.iter().find(|f| f.path.ends_with("tensor/kernels.rs")) else {
        // Fixture sets without a kernels file have nothing to audit.
        return findings;
    };
    let kernels_code = kernels.code_text();
    let corpus = test_corpus(files);
    for reg in registrations(kernels) {
        for replica in replicas_for(&reg.field) {
            if !contains_ident(&kernels_code, replica) {
                findings.push(Finding::new(
                    RULE_REPLICA,
                    &kernels.path,
                    reg.line,
                    &format!(
                        "dispatch field `{}` registers `{}` but its scalar replica \
                         `{replica}` is not defined in the kernels module",
                        reg.field, reg.func
                    ),
                ));
            }
        }
        let base = reg.func.strip_suffix("_entry").unwrap_or(&reg.func);
        if !contains_ident(&corpus, base) && !contains_ident(&corpus, &reg.func) {
            findings.push(Finding::new(
                RULE_TEST_REF,
                &kernels.path,
                reg.line,
                &format!(
                    "dispatch field `{}` registers `{}` but no test references \
                     `{base}` by name (tests/*.rs or a #[cfg(test)] region)",
                    reg.field, reg.func
                ),
            ));
        }
    }
    findings
}

/// Every `field: func` pair inside `KernelTable { .. }` / `I8Kernels
/// { .. }` literals (skipping `I8Kernels` type ascriptions etc. by
/// requiring the literal-brace form).
fn registrations(kernels: &SourceFile) -> Vec<Registration> {
    let mut out = Vec::new();
    for (kind, fields) in [("KernelTable", TABLE_FIELDS), ("I8Kernels", I8_FIELDS)] {
        for (i, line) in kernels.code.iter().enumerate() {
            for at in super::source::ident_positions(line, kind) {
                let after = line[at + kind.len()..].trim_start();
                if !after.starts_with('{') {
                    continue;
                }
                // `struct KernelTable {`, `impl KernelTable {`, and
                // `-> KernelTable {` (a fn signature whose *body* brace
                // follows) are not literals.
                let before = line[..at].trim_end();
                if before.ends_with("struct")
                    || before.ends_with("impl")
                    || before.ends_with("->")
                    || before.ends_with("dyn")
                {
                    continue;
                }
                let col = line[at..].find('{').map(|o| at + o).unwrap();
                let Some((end_line, _)) = super::source::matching_brace(&kernels.code, i, col)
                else {
                    continue;
                };
                for (j, body_line) in
                    kernels.code.iter().enumerate().take(end_line + 1).skip(i)
                {
                    for &field in fields {
                        if let Some(func) = field_value(body_line, field) {
                            out.push(Registration { field: field.into(), func, line: j + 1 });
                        }
                    }
                }
            }
        }
    }
    out
}

/// Parse `field: ident`, `field: Some(ident)`, or `field: &ident` from a
/// struct-literal line; `None` for `field: None` and non-identifier
/// values.
fn field_value(line: &str, field: &str) -> Option<String> {
    let t = line.trim_start();
    let rest = t.strip_prefix(field)?;
    let rest = rest.trim_start().strip_prefix(':')?.trim_start();
    let rest = rest.strip_prefix("Some(").unwrap_or(rest);
    let rest = rest.strip_prefix('&').unwrap_or(rest);
    let ident: String = rest
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if ident.is_empty() || ident == "None" {
        return None;
    }
    Some(ident)
}

/// Concatenated test text: all of `tests/` plus everything from the
/// first `#[cfg(test)]` marker to EOF in each `src/` file (test mods sit
/// at file end by repo convention).
fn test_corpus(files: &[SourceFile]) -> String {
    let mut corpus = String::new();
    for f in files {
        if f.path.starts_with("tests/") {
            corpus.push_str(&f.code_text());
            corpus.push('\n');
        } else if f.path.starts_with("src/") {
            if let Some(at) = f.lines.iter().position(|l| l.contains("#[cfg(test)]")) {
                for l in &f.code[at..] {
                    corpus.push_str(l);
                    corpus.push('\n');
                }
            }
        }
    }
    corpus
}
