//! Rule family 1: the unsafe audit.
//!
//! Three hard errors, mirroring the repo's safety story:
//!
//! 1. **Containment** — `unsafe` may appear only in the allowlisted
//!    modules ([`UNSAFE_ALLOWLIST`]): the SIMD kernels, the packed GEMM
//!    drivers, the pool, the scratch arenas, the `nn::fff` gather/shard
//!    paths, and the counting allocator of the alloc-regression harness.
//!    Anything else must be written in safe Rust (and historically is).
//! 2. **Documentation** — every `unsafe` block / `unsafe impl` carries a
//!    `// SAFETY:` comment directly above it (attributes and further
//!    comment lines may intervene); every `unsafe fn` carries either a
//!    `/// # Safety` doc section or a `// SAFETY:` comment. The comment
//!    must state the pointer/aliasing/ISA precondition — the analyzer
//!    can only check presence, but clippy's
//!    `undocumented_unsafe_blocks` backs this same contract in CI.
//! 3. **Crate lint** — `src/lib.rs` must carry
//!    `#![deny(unsafe_op_in_unsafe_fn)]`, so an `unsafe fn` body gets no
//!    implicit blanket permission: each unsafe operation needs its own
//!    commented block.
//!
//! `unsafe fn` *types* (`type T = unsafe fn(..)`, fn-pointer fields,
//! `-> unsafe fn`) declare contracts rather than perform operations and
//! are exempt.

use super::source::SourceFile;
use super::Finding;

/// Files allowed to contain `unsafe` (repo-relative, `/`-separated).
/// Extending it is a deliberate act: add the path here *and* document
/// the module's aliasing model in EXPERIMENTS.md §Analysis.
pub const UNSAFE_ALLOWLIST: &[&str] = &[
    "src/tensor/kernels.rs",
    "src/tensor/gemm.rs",
    "src/tensor/pool.rs",
    "src/tensor/scratch.rs",
    "src/nn/fff.rs",
    "tests/alloc_regression.rs",
];

const RULE_ALLOWLIST: &str = "unsafe-outside-allowlist";
const RULE_UNDOCUMENTED: &str = "undocumented-unsafe";
const RULE_CRATE_LINT: &str = "missing-unsafe-op-lint";

/// Kinds of `unsafe` occurrence the scanner distinguishes.
#[derive(PartialEq)]
enum Site {
    Block,
    Fn,
    Impl,
    TypePosition,
}

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut saw_lib = false;
    for f in files {
        if f.path == "src/lib.rs" {
            saw_lib = true;
            let has_lint = f
                .code
                .iter()
                .zip(&f.lines)
                .any(|(_, l)| l.contains("#![deny(unsafe_op_in_unsafe_fn)]"));
            if !has_lint {
                findings.push(Finding::new(
                    RULE_CRATE_LINT,
                    &f.path,
                    1,
                    "src/lib.rs must carry #![deny(unsafe_op_in_unsafe_fn)]",
                ));
            }
        }
        for (i, code_line) in f.code.iter().enumerate() {
            for col in super::source::ident_positions(code_line, "unsafe") {
                let site = classify(f, i, col);
                if site == Site::TypePosition {
                    continue;
                }
                if !UNSAFE_ALLOWLIST.contains(&f.path.as_str()) {
                    findings.push(Finding::new(
                        RULE_ALLOWLIST,
                        &f.path,
                        i + 1,
                        "unsafe outside the allowlisted modules (see \
                         analysis::unsafe_audit::UNSAFE_ALLOWLIST)",
                    ));
                }
                let documented = match site {
                    Site::Fn => has_safety_comment(f, i) || has_safety_doc(f, i),
                    _ => has_safety_comment(f, i),
                };
                if !documented {
                    findings.push(Finding::new(
                        RULE_UNDOCUMENTED,
                        &f.path,
                        i + 1,
                        "unsafe without a // SAFETY: comment (unsafe fn \
                         alternatively takes a /// # Safety doc section)",
                    ));
                }
            }
        }
    }
    let _ = saw_lib; // fixture sets may omit lib.rs entirely; that's fine
    findings
}

/// Classify the `unsafe` token at (`line`, `col`) of the code view by
/// what *follows* it: `impl`/`trait`, `fn name` (a declaration),
/// `fn(` (a fn-pointer type), or anything else (an unsafe block —
/// including `= unsafe {` expression positions).
fn classify(f: &SourceFile, line: usize, col: usize) -> Site {
    let mut after = f.code[line][col + "unsafe".len()..].trim_start().to_string();
    let mut li = line;
    while after.is_empty() && li + 1 < f.code.len() {
        li += 1;
        after = f.code[li].trim_start().to_string();
    }
    if after.starts_with("impl") || after.starts_with("trait") {
        return Site::Impl;
    }
    if let Some(rest) = after.strip_prefix("fn") {
        if rest.trim_start().starts_with('(') {
            return Site::TypePosition;
        }
        return Site::Fn;
    }
    Site::Block
}

/// Walk upward from the unsafe site looking for `needle` in a comment.
/// Skips comment lines, attribute lines, and statement-continuation
/// heads (a code line ending in `=`, `(`, `,`, or an operator — the
/// comment legitimately sits above the whole wrapped statement, which
/// is also where clippy's `undocumented_unsafe_blocks` accepts it).
fn comment_above_contains(f: &SourceFile, line: usize, needle: &str) -> bool {
    let mut i = line;
    while i > 0 {
        i -= 1;
        if f.is_comment_line(i) {
            if f.lines[i].contains(needle) {
                return true;
            }
            continue;
        }
        if f.is_attr_line(i) {
            continue;
        }
        let code = f.code[i].trim_end();
        let continuation = code.ends_with('=')
            || code.ends_with('(')
            || code.ends_with(',')
            || code.ends_with("&&")
            || code.ends_with("||")
            || code.ends_with('+');
        if !continuation {
            return false;
        }
    }
    false
}

/// `// SAFETY:` comment above an unsafe block/impl.
fn has_safety_comment(f: &SourceFile, line: usize) -> bool {
    comment_above_contains(f, line, "SAFETY:")
}

/// `/// # Safety` doc section above an `unsafe fn`.
fn has_safety_doc(f: &SourceFile, line: usize) -> bool {
    comment_above_contains(f, line, "# Safety")
}
