//! Rule family 3: determinism lints.
//!
//! The repo's bit-identity contract (results independent of
//! `FFF_THREADS` and of allocator/hash state) has two known failure
//! shapes, and each gets a lint:
//!
//! * **Rule A — `hashmap-order-float-accumulation`**: iterating a
//!   `HashMap`/`HashSet` and folding floats with `+=` inside the loop.
//!   Iteration order is randomized per process, so the float sum is not
//!   reproducible. Fix: collect-and-sort keys, or use an index-ordered
//!   `Vec`.
//! * **Rule B — `pool-reduction-thread-dependent`**: a
//!   `ThreadPool::run(tasks, ..)` region whose task count derives from
//!   `.threads()` / `available_parallelism` *and* whose inline closure
//!   accumulates with `+=`. Per-thread partials folded in thread order
//!   change with the thread count. Fix: route reductions through the
//!   fixed-shard helpers (`n_shards` / `TRAIN_SHARD_ROWS`-derived
//!   counts), which shard by *batch* geometry.
//!
//! Both lints are narrow by design: they key on the accumulation
//! operator actually appearing inside the traced region, so
//! thread-count-sized *tiling* (no cross-task arithmetic) stays legal.

use super::source::{ident_positions, matching_brace, SourceFile};
use super::Finding;

const RULE_HASH_ORDER: &str = "hashmap-order-float-accumulation";
const RULE_POOL_REDUCTION: &str = "pool-reduction-thread-dependent";

/// How many `let`-binding hops Rule B follows from a `.run()` argument
/// back toward `.threads()`.
const TRACE_DEPTH: usize = 4;

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    for f in files {
        check_hash_order(f, &mut findings);
        check_pool_reduction(f, &mut findings);
    }
    findings
}

// ---------------------------------------------------------------- Rule A

fn check_hash_order(f: &SourceFile, findings: &mut Vec<Finding>) {
    // Names bound to hash containers anywhere in the file (`let [mut] x
    // : HashMap<..>` / `= HashMap::new()` / `HashSet`). File-scoped:
    // shadowing across functions can over-approximate, which for a lint
    // that demands *ordered* iteration is the safe direction.
    let mut hash_names: Vec<String> = Vec::new();
    for line in &f.code {
        if !(line.contains("HashMap") || line.contains("HashSet")) {
            continue;
        }
        if let Some(name) = let_binding_name(line) {
            hash_names.push(name);
        }
    }
    if hash_names.is_empty() {
        return;
    }
    for (i, line) in f.code.iter().enumerate() {
        let Some(for_col) = ident_positions(line, "for").first().copied() else {
            continue;
        };
        let Some(in_rel) = line[for_col..].find(" in ") else {
            continue;
        };
        let iterated = &line[for_col + in_rel + 4..];
        if !hash_names.iter().any(|n| !ident_positions(iterated, n).is_empty()) {
            continue;
        }
        // Loop body: brace-match from the `{` opening this `for`.
        let Some(open) = line.rfind('{') else { continue };
        let Some((end_line, _)) = matching_brace(&f.code, i, open) else {
            continue;
        };
        let body_accumulates = f.code[i..=end_line]
            .iter()
            .any(|l| l.contains("+=") || l.contains("-=") || l.contains("*="));
        if body_accumulates {
            findings.push(Finding::new(
                RULE_HASH_ORDER,
                &f.path,
                i + 1,
                "float accumulation over HashMap/HashSet iteration order; \
                 sort the keys (or use an index-ordered Vec) before folding",
            ));
        }
    }
}

/// `let [mut] name` pattern → the bound identifier.
fn let_binding_name(code_line: &str) -> Option<String> {
    let at = ident_positions(code_line, "let").first().copied()?;
    let mut rest = code_line[at + 3..].trim_start();
    if let Some(r) = rest.strip_prefix("mut ") {
        rest = r.trim_start();
    }
    let name: String = rest
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if name.is_empty() {
        None
    } else {
        Some(name)
    }
}

// ---------------------------------------------------------------- Rule B

fn check_pool_reduction(f: &SourceFile, findings: &mut Vec<Finding>) {
    let bindings = collect_let_bindings(f);
    for (i, line) in f.code.iter().enumerate() {
        let mut from = 0usize;
        while let Some(rel) = line[from..].find(".run(") {
            let open = from + rel + ".run".len();
            from = open + 1;
            let Some(args) = paren_args(&f.code, i, open) else {
                continue;
            };
            // Pool regions are `.run(n_tasks, &closure)`; one-argument
            // `run` calls (trainer.run(model), exe.run(&inputs)) are
            // different APIs and skipped.
            if args.len() != 2 {
                continue;
            }
            let task_count = args[0].trim();
            let body = args[1].trim();
            if !body.starts_with('&') {
                continue;
            }
            if !body.contains('|') {
                // `&task_fn` by name: not an inline closure, the lint
                // cannot see the body — out of scope by design.
                continue;
            }
            let accumulates =
                body.contains("+=") || body.contains("-=") || body.contains("*=");
            if !accumulates {
                continue;
            }
            if traces_to_thread_count(task_count, &bindings, TRACE_DEPTH) {
                findings.push(Finding::new(
                    RULE_POOL_REDUCTION,
                    &f.path,
                    i + 1,
                    "pool reduction whose task count derives from the thread \
                     count; shard by batch geometry (fixed-shard helpers) so \
                     results are FFF_THREADS-invariant",
                ));
            }
        }
    }
}

/// Does `expr` (transitively through `let` bindings, up to `depth`
/// hops) reach `.threads()` or `available_parallelism`?
fn traces_to_thread_count(expr: &str, bindings: &[(String, String)], depth: usize) -> bool {
    if expr.contains(".threads()") || expr.contains("available_parallelism") {
        return true;
    }
    if depth == 0 {
        return false;
    }
    for (name, rhs) in bindings {
        if !ident_positions(expr, name).is_empty()
            && traces_to_thread_count(rhs, bindings, depth - 1)
        {
            return true;
        }
    }
    false
}

/// All `let name = rhs;` bindings in the file's code view. The rhs is
/// captured until the terminating `;` (up to a few lines), enough for
/// the arithmetic chains the trace follows.
fn collect_let_bindings(f: &SourceFile) -> Vec<(String, String)> {
    let mut out = Vec::new();
    for (i, line) in f.code.iter().enumerate() {
        if ident_positions(line, "let").is_empty() {
            continue;
        }
        let Some(name) = let_binding_name(line) else { continue };
        let Some(eq) = line.find('=') else { continue };
        let mut rhs = line[eq + 1..].to_string();
        let mut j = i;
        while !rhs.contains(';') && j + 1 < f.code.len() && j < i + 4 {
            j += 1;
            rhs.push(' ');
            rhs.push_str(&f.code[j]);
        }
        if let Some(semi) = rhs.find(';') {
            rhs.truncate(semi);
        }
        // Guard against `name` appearing in its own rhs (`let x = x+1;`
        // shadowing) which would loop the trace; depth bounds it anyway,
        // but dropping self-references keeps traces meaningful.
        if ident_positions(&rhs, &name).is_empty() {
            out.push((name, rhs));
        }
    }
    out
}

/// Split the parenthesized argument list opening at (`line`, `col`)
/// into top-level (depth-1) comma-separated pieces. Spans lines.
fn paren_args(code: &[String], line: usize, col: usize) -> Option<Vec<String>> {
    let mut depth = 0i64;
    let mut brace = 0i64;
    let mut args: Vec<String> = Vec::new();
    let mut cur = String::new();
    for (li, l) in code.iter().enumerate().skip(line) {
        let start = if li == line { col } else { 0 };
        for ch in l.bytes().skip(start) {
            match ch {
                b'(' | b'[' => {
                    depth += 1;
                    if depth > 1 {
                        cur.push(ch as char);
                    }
                }
                b')' | b']' => {
                    depth -= 1;
                    if depth == 0 {
                        args.push(cur);
                        return Some(args);
                    }
                    cur.push(ch as char);
                }
                b'{' => {
                    brace += 1;
                    cur.push('{');
                }
                b'}' => {
                    brace -= 1;
                    cur.push('}');
                }
                b',' if depth == 1 && brace == 0 => {
                    args.push(std::mem::take(&mut cur));
                }
                _ => {
                    if depth >= 1 {
                        cur.push(ch as char);
                    }
                }
            }
        }
        if depth >= 1 {
            cur.push('\n');
        }
    }
    None
}
