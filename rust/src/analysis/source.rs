//! Lightweight Rust source scanner for the audit rules (std-only).
//!
//! The rules need just enough lexical structure to be trustworthy:
//! which bytes are *code* versus comment/string, and where braces
//! balance. This module produces, per file, the original lines plus a
//! parallel `code` view in which comments and literal *contents* are
//! blanked out (replaced by spaces, structure preserved), so rule
//! regexes can match `unsafe`, `for`, `.run(` etc. without being fooled
//! by a string literal or a doc comment that merely mentions them. The
//! original lines stay available for the one thing comments are
//! load-bearing for: `// SAFETY:` detection.

/// One scanned source file: original text and the code-only view.
pub struct SourceFile {
    /// Repo-relative, `/`-separated path (e.g. `src/tensor/pool.rs`).
    pub path: String,
    /// Original lines, verbatim.
    pub lines: Vec<String>,
    /// Lines with comments and string/char contents blanked to spaces.
    /// Same line count and per-line byte length as `lines`.
    pub code: Vec<String>,
}

/// Lexer state carried across lines.
enum Mode {
    Code,
    Block(u32),
    Str,
    RawStr(u32),
}

impl SourceFile {
    pub fn from_text(path: &str, text: &str) -> SourceFile {
        let lines: Vec<String> = text.split('\n').map(str::to_string).collect();
        let code = blank_noise(&lines);
        SourceFile { path: path.replace('\\', "/"), lines, code }
    }

    /// Whole code view joined with `\n` (for multi-line regex-ish scans).
    pub fn code_text(&self) -> String {
        self.code.join("\n")
    }

    /// True if the original line `i` is a comment line (`//` or `///`).
    pub fn is_comment_line(&self, i: usize) -> bool {
        let t = self.lines[i].trim_start();
        t.starts_with("//")
    }

    /// True if the original line `i` is an attribute line (`#[...]` /
    /// `#![...]`).
    pub fn is_attr_line(&self, i: usize) -> bool {
        let t = self.lines[i].trim_start();
        t.starts_with("#[") || t.starts_with("#![")
    }
}

/// Blank comments and literal contents out of `lines`, preserving line
/// structure and byte offsets. Handles `//` comments, nested `/* */`,
/// plain and raw strings, and simple char literals; that is the full
/// lexical surface this crate uses.
fn blank_noise(lines: &[String]) -> Vec<String> {
    let mut out = Vec::with_capacity(lines.len());
    let mut mode = Mode::Code;
    for line in lines {
        let b = line.as_bytes();
        let mut o: Vec<u8> = Vec::with_capacity(b.len());
        let mut i = 0usize;
        while i < b.len() {
            match mode {
                Mode::Block(depth) => {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        mode = Mode::Block(depth + 1);
                        o.extend_from_slice(b"  ");
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                        mode = if depth == 1 { Mode::Code } else { Mode::Block(depth - 1) };
                        o.extend_from_slice(b"  ");
                        i += 2;
                    } else {
                        o.push(b' ');
                        i += 1;
                    }
                }
                Mode::Str => {
                    if b[i] == b'\\' && i + 1 < b.len() {
                        o.extend_from_slice(b"  ");
                        i += 2;
                    } else if b[i] == b'"' {
                        mode = Mode::Code;
                        o.push(b'"');
                        i += 1;
                    } else {
                        o.push(b' ');
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    if b[i] == b'"' {
                        let h = hashes as usize;
                        if i + 1 + h <= b.len() && b[i + 1..i + 1 + h].iter().all(|&c| c == b'#') {
                            mode = Mode::Code;
                            o.push(b'"');
                            o.extend(std::iter::repeat_n(b'#', h));
                            i += 1 + h;
                        } else {
                            o.push(b' ');
                            i += 1;
                        }
                    } else {
                        o.push(b' ');
                        i += 1;
                    }
                }
                Mode::Code => {
                    if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'/' {
                        // Line comment: blank the rest of the line.
                        o.extend(std::iter::repeat_n(b' ', b.len() - i));
                        i = b.len();
                    } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                        mode = Mode::Block(1);
                        o.extend_from_slice(b"  ");
                        i += 2;
                    } else if b[i] == b'"' {
                        mode = Mode::Str;
                        o.push(b'"');
                        i += 1;
                    } else if b[i] == b'r'
                        && i + 1 < b.len()
                        && (b[i + 1] == b'"' || b[i + 1] == b'#')
                        && !prev_is_ident(&o)
                    {
                        let mut h = 0usize;
                        while i + 1 + h < b.len() && b[i + 1 + h] == b'#' {
                            h += 1;
                        }
                        if i + 1 + h < b.len() && b[i + 1 + h] == b'"' {
                            mode = Mode::RawStr(h as u32);
                            o.push(b'r');
                            o.extend(std::iter::repeat_n(b'#', h));
                            o.push(b'"');
                            i += 2 + h;
                        } else {
                            o.push(b[i]);
                            i += 1;
                        }
                    } else if b[i] == b'\'' {
                        // Char literal vs lifetime: a literal closes with
                        // `'` within a few bytes (`'x'`, `'\n'`, `'\u{..}'`).
                        if let Some(close) = char_literal_end(b, i) {
                            o.push(b'\'');
                            o.extend(std::iter::repeat_n(b' ', close - i - 1));
                            o.push(b'\'');
                            i = close + 1;
                        } else {
                            o.push(b'\'');
                            i += 1;
                        }
                    } else {
                        o.push(b[i]);
                        i += 1;
                    }
                }
            }
        }
        // A line comment never spans lines; `Mode::Str` legitimately can
        // (multi-line string literals) and the state carries over.
        out.push(String::from_utf8(o).expect("blanking preserves UTF-8 boundaries"));
    }
    out
}

fn prev_is_ident(o: &[u8]) -> bool {
    o.last().is_some_and(|&c| c == b'_' || c.is_ascii_alphanumeric())
}

/// If `b[start]` opens a char literal, return the index of its closing
/// quote; `None` for lifetimes like `'static`.
fn char_literal_end(b: &[u8], start: usize) -> Option<usize> {
    let mut j = start + 1;
    if j < b.len() && b[j] == b'\\' {
        // Escape: find the next `'`, bounded (covers `\u{1F600}`).
        let lim = (start + 12).min(b.len());
        while j < lim {
            j += 1;
            if j < b.len() && b[j] == b'\'' {
                return Some(j);
            }
        }
        return None;
    }
    // Plain char: exactly one scalar then `'`. Multi-byte UTF-8 ok.
    let mut k = j;
    while k < b.len() && k < j + 4 && (b[k] & 0xC0) == 0x80 || k == j {
        k += 1;
    }
    if k < b.len() && b[k] == b'\'' && k > j {
        return Some(k);
    }
    None
}

/// Find the matching `}` for the `{` at (`line`, `col`) in `code`.
/// Returns `(line, col)` of the closing brace, or `None` if unbalanced.
pub fn matching_brace(code: &[String], line: usize, col: usize) -> Option<(usize, usize)> {
    let mut depth = 0i64;
    for (li, l) in code.iter().enumerate().skip(line) {
        let start = if li == line { col } else { 0 };
        for (ci, ch) in l.bytes().enumerate().skip(start) {
            match ch {
                b'{' => depth += 1,
                b'}' => {
                    depth -= 1;
                    if depth == 0 {
                        return Some((li, ci));
                    }
                }
                _ => {}
            }
        }
    }
    None
}

/// Byte offsets of every match of identifier `word` in `text`, matched
/// on identifier boundaries (`[A-Za-z0-9_]`).
pub fn ident_positions(text: &str, word: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let tb = text.as_bytes();
    let mut from = 0usize;
    while let Some(rel) = text[from..].find(word) {
        let at = from + rel;
        let before_ok =
            at == 0 || !(tb[at - 1] == b'_' || tb[at - 1].is_ascii_alphanumeric());
        let after = at + word.len();
        let after_ok =
            after >= tb.len() || !(tb[after] == b'_' || tb[after].is_ascii_alphanumeric());
        if before_ok && after_ok {
            out.push(at);
        }
        from = at + word.len().max(1);
    }
    out
}

/// True if identifier `word` occurs anywhere in `text`.
pub fn contains_ident(text: &str, word: &str) -> bool {
    !ident_positions(text, word).is_empty()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_and_strings_are_blanked() {
        let f = SourceFile::from_text(
            "src/x.rs",
            "let s = \"unsafe { }\"; // unsafe here too\nlet c = '{';\n/* unsafe\n spans */ let x = 1;",
        );
        assert!(!f.code[0].contains("unsafe"));
        assert!(f.code[0].contains("let s ="));
        assert!(!f.code[1].contains('{'));
        assert!(!f.code[2].contains("unsafe"));
        assert!(f.code[3].contains("let x = 1;"));
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let f = SourceFile::from_text(
            "src/x.rs",
            "let r = r#\"for x in map { }\"#;\nfn f<'a>(x: &'a str) {}",
        );
        assert!(!f.code[0].contains("for"));
        assert!(f.code[1].contains("fn f<'a>"));
    }

    #[test]
    fn brace_matching() {
        let f = SourceFile::from_text("src/x.rs", "fn f() {\n  if x { y(); }\n}");
        let open = f.code[0].find('{').unwrap();
        assert_eq!(matching_brace(&f.code, 0, open), Some((2, 0)));
    }

    #[test]
    fn ident_boundaries() {
        assert!(contains_ident("call(micro_4x8_ref)", "micro_4x8_ref"));
        assert!(!contains_ident("micro_4x8_ref_epi", "micro_4x8_ref"));
    }
}
