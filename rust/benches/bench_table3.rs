//! `cargo bench --bench bench_table3` — regenerates the paper's table3
//! (FFF_SCALE=smoke|paper; see rust/src/experiments/table3.rs).

fn main() {
    let scale = fastfeedforward::bench::Scale::from_env();
    println!("scale: {scale:?} (set FFF_SCALE=paper for the full grid)");
    let t0 = std::time::Instant::now();
    fastfeedforward::experiments::table3::run(scale);
    println!("[bench_table3] total wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
