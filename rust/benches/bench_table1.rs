//! `cargo bench --bench bench_table1` — regenerates the paper's table1
//! (FFF_SCALE=smoke|paper; see rust/src/experiments/table1.rs).

fn main() {
    let scale = fastfeedforward::bench::Scale::from_env();
    println!("scale: {scale:?} (set FFF_SCALE=paper for the full grid)");
    let t0 = std::time::Instant::now();
    fastfeedforward::experiments::table1::run(scale);
    println!("[bench_table1] total wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
