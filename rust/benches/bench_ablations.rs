//! Ablations over the design choices DESIGN.md calls out:
//!
//! A. **Hardening level** — the FORWARD_T → FORWARD_I accuracy gap as a
//!    function of `h` (the paper's core hardening claim, quantified).
//! B. **Randomized child transposition** — the localized-overfitting
//!    mitigation on a deep, small-leaf (overfragmentation-prone) config.
//! C. **Node width n** — the paper uses n = 1 everywhere and reports it
//!    suffices; verify wider node networks buy nothing at equal budget.
//!
//! `cargo bench --bench bench_ablations` (FFF_SCALE=paper for more seeds).

use fastfeedforward::bench::{Scale, Table};
use fastfeedforward::config::{ModelKind, TrainConfig};
use fastfeedforward::data::DatasetKind;
use fastfeedforward::nn::{accuracy, Fff, FffConfig, Model};
use fastfeedforward::rng::Rng;
use fastfeedforward::train::Trainer;

fn main() {
    let scale = Scale::from_env();
    ablation_hardening(scale);
    ablation_transposition(scale);
    ablation_node_width(scale);
}

fn base_cfg(scale: Scale) -> TrainConfig {
    let mut c = TrainConfig::table1(DatasetKind::Mnist, ModelKind::Fff, 64, 8, 0);
    let (tn, te) = scale.pick((1200, 300), (8000, 2000));
    c.train_n = tn;
    c.test_n = te;
    c.max_epochs = scale.pick(15, 120);
    c.patience = scale.pick(8, 25);
    c
}

/// A: train at several h, report soft-vs-hard accuracy gap.
fn ablation_hardening(scale: Scale) {
    let mut table = Table::new(
        "ablation A — hardening level vs FORWARD_T/FORWARD_I gap (MNIST, w=64 l=8)",
        &["h", "soft acc (T)", "hard acc (I)", "gap", "final mean entropy"],
    );
    for h in [0.0f32, 0.3, 1.0, 3.0, 10.0] {
        let mut cfg = base_cfg(scale);
        cfg.hardening = h;
        let trainer = Trainer::from_config(&cfg);
        let mut rng = Rng::seed_from_u64(0);
        let mut fc = FffConfig::new(
            trainer.train.dim(),
            trainer.train.num_classes,
            cfg.fff_depth(),
            cfg.leaf,
        );
        fc.hardening = h;
        let mut fff = Fff::new(&mut rng, fc);
        let _ = trainer.run(&mut fff);
        let x = &trainer.test.images;
        let soft = {
            let mut r = Rng::seed_from_u64(1);
            accuracy(&fff.forward_train(x, &mut r), &trainer.test.labels)
        };
        let hard = accuracy(&fff.forward_infer(x), &trainer.test.labels);
        let ent: f32 =
            fff.last_entropies.iter().sum::<f32>() / fff.last_entropies.len().max(1) as f32;
        table.row(vec![
            format!("{h}"),
            format!("{:.2}%", soft * 100.0),
            format!("{:.2}%", hard * 100.0),
            format!("{:+.2}pp", (soft - hard) * 100.0),
            format!("{ent:.4}"),
        ]);
    }
    table.print();
    println!("expected: higher h → lower entropy → smaller T/I gap; at h=0 the gap");
    println!("depends on self-hardening.\n");
}

/// B: deep small-leaf FFF with and without child transposition.
fn ablation_transposition(scale: Scale) {
    let mut table = Table::new(
        "ablation B — randomized child transposition (USPS, w=64 l=1 d=6)",
        &["transposition_p", "M_A", "G_A"],
    );
    for p in [0.0f32, 0.05, 0.15] {
        let mut cfg = base_cfg(scale);
        cfg.dataset = DatasetKind::Usps;
        cfg.leaf = 1;
        cfg.width = 64;
        cfg.transposition_p = p;
        let out = fastfeedforward::train::run_training(&cfg);
        table.row(vec![
            format!("{p}"),
            format!("{:.2}%", out.memorization_accuracy * 100.0),
            format!("{:.2}%", out.generalization_accuracy * 100.0),
        ]);
    }
    table.print();
    println!("expected: small p narrows the M_A−G_A overfitting gap on deep,");
    println!("small-leaf (overfragmentation-prone) configurations.\n");
}

/// C: node width n = 1 vs wider node networks at equal leaf budget.
fn ablation_node_width(scale: Scale) {
    let mut table = Table::new(
        "ablation C — node width n (MNIST, w=64 l=8 d=3)",
        &["n", "M_A", "G_A"],
    );
    for n in [1usize, 4] {
        let cfg = base_cfg(scale);
        let trainer = Trainer::from_config(&cfg);
        let mut rng = Rng::seed_from_u64(0);
        let mut fc = FffConfig::new(
            trainer.train.dim(),
            trainer.train.num_classes,
            cfg.fff_depth(),
            cfg.leaf,
        );
        fc.node = n;
        fc.hardening = cfg.hardening;
        let mut fff = Fff::new(&mut rng, fc);
        let out = trainer.run(&mut fff);
        table.row(vec![
            n.to_string(),
            format!("{:.2}%", out.memorization_accuracy * 100.0),
            format!("{:.2}%", out.generalization_accuracy * 100.0),
        ]);
    }
    table.print();
    println!("expected: n = 1 suffices (the paper's finding) — wider node networks");
    println!("don't buy accuracy at this scale.");
}
