//! `cargo bench --bench bench_table2` — regenerates the paper's table2
//! (FFF_SCALE=smoke|paper; see rust/src/experiments/table2.rs).

fn main() {
    let scale = fastfeedforward::bench::Scale::from_env();
    println!("scale: {scale:?} (set FFF_SCALE=paper for the full grid)");
    let t0 = std::time::Instant::now();
    fastfeedforward::experiments::table2::run(scale);
    println!("[bench_table2] total wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
