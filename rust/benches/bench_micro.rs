//! Micro-benchmarks of the hot paths the experiments lean on: the GEMM
//! kernel, the FFF routing descent, single-leaf inference, and the
//! coordinator's batching overhead. These are the §Perf instruments
//! (EXPERIMENTS.md §Perf records their before/after).

use fastfeedforward::bench::{time_budgeted, time_fn, Table};
use fastfeedforward::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, NativeFffBackend};
use fastfeedforward::nn::{Ff, FffInfer};
use fastfeedforward::rng::Rng;
use fastfeedforward::tensor::{gemm, Matrix};
use std::time::Duration;

fn main() {
    let mut table = Table::new("micro-benchmarks", &["name", "time", "derived"]);
    let mut rng = Rng::seed_from_u64(0);

    // GEMM peaks (the FF baseline's engine).
    for &(m, k, n) in &[(256usize, 768usize, 768usize), (256, 784, 128), (2048, 768, 32)] {
        let mut a = Matrix::zeros(m, k);
        let mut b = Matrix::zeros(k, n);
        rng.fill_normal(a.as_mut_slice(), 0.0, 1.0);
        rng.fill_normal(b.as_mut_slice(), 0.0, 1.0);
        let t = time_budgeted(Duration::from_millis(500), 5, 1000, || {
            std::hint::black_box(gemm(&a, &b));
        });
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        table.row(vec![
            format!("gemm {m}x{k}x{n}"),
            format!("{:.3} ms", t.mean_ms()),
            format!("{:.2} GFLOP/s", flops / t.mean.as_secs_f64() / 1e9),
        ]);
    }

    // FFF routing descent only (the O(d) mechanism).
    for &depth in &[4usize, 8, 12] {
        let inf = FffInfer::random(&mut rng, 768, 768, depth, 32, 1 << 10);
        let xs: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..768).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let t = time_budgeted(Duration::from_millis(300), 5, 100_000, || {
            for x in &xs {
                std::hint::black_box(inf.route(x));
            }
        });
        table.row(vec![
            format!("fff route d={depth} (64 samples)"),
            format!("{:.1} us", t.mean_us()),
            format!("{:.2} us/sample", t.mean_us() / 64.0),
        ]);
    }

    // Single-sample leaf inference (serving hot path).
    {
        let inf = FffInfer::random(&mut rng, 784, 10, 4, 8, 16);
        let x: Vec<f32> = (0..784).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut out = vec![0.0f32; 10];
        let t = time_budgeted(Duration::from_millis(300), 100, 1_000_000, || {
            inf.infer_one(std::hint::black_box(&x), &mut out);
        });
        table.row(vec![
            "fff infer_one 784->10 (d=4 l=8)".into(),
            format!("{:.2} us", t.mean_us()),
            String::new(),
        ]);
    }

    // FF vs FFF batched inference at MNIST dims (quickstart's comparison).
    {
        let ff = Ff::new(&mut rng, 784, 64, 10).compile_infer();
        let fff = FffInfer::random(&mut rng, 784, 10, 3, 8, 8);
        let mut x = Matrix::zeros(256, 784);
        rng.fill_normal(x.as_mut_slice(), 0.0, 1.0);
        let t_ff = time_fn(3, 30, || {
            std::hint::black_box(ff.infer_batch(&x));
        });
        let t_fff = time_fn(3, 30, || {
            std::hint::black_box(fff.infer_batch(&x));
        });
        table.row(vec![
            "ff w=64 batch 256 (784->10)".into(),
            format!("{:.3} ms", t_ff.mean_ms()),
            String::new(),
        ]);
        table.row(vec![
            "fff d=3 l=8 batch 256 (784->10)".into(),
            format!("{:.3} ms", t_fff.mean_ms()),
            format!("{:.2}x vs ff", t_ff.mean.as_secs_f64() / t_fff.mean.as_secs_f64()),
        ]);
    }

    // Coordinator batching overhead: submit->response with a tiny model.
    {
        let model = FffInfer::random(&mut rng, 16, 4, 2, 2, 4);
        let coord = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { max_batch: 32, max_delay: Duration::from_micros(100) },
                workers: 1,
                queue_capacity: 10_000,
            },
            move || Box::new(NativeFffBackend::new(model.clone())),
        );
        let t = time_budgeted(Duration::from_millis(500), 20, 50_000, || {
            let rx = coord.submit(vec![0.1; 16]).unwrap();
            std::hint::black_box(rx.recv().unwrap());
        });
        table.row(vec![
            "coordinator round-trip (1 in flight)".into(),
            format!("{:.1} us", t.mean_us()),
            "incl. 100us batch deadline".into(),
        ]);
        coord.shutdown();
    }

    table.print();
}
