//! Micro-benchmarks of the hot paths the experiments lean on: the GEMM
//! kernel, the FFF routing descent, single-leaf inference, and the
//! coordinator's batching overhead. These are the §Perf instruments
//! (EXPERIMENTS.md §Perf records their before/after).
//!
//! The run starts with the **gemm/fff_infer thread-scaling suite** (fixed
//! seeds, 1/2/4/8 threads, every GEMM kernel kind forced in turn and each
//! row labelled with the kernel + detected ISA) plus the
//! **fused-vs-separate epilogue suite** (bias+ReLU in the store phase vs
//! an elementwise pass), the **scratch-arena suite** (retained
//! `InferScratch` vs the allocating wrappers at batch 4096, depth 8),
//! the **routing-descent suite** (depths 4–15, 1/2/4 threads), and the
//! **training-engine suite** (level-batched GEMM training vs the
//! per-node baseline on the Table-2-shaped workload, 1/2/4 threads),
//! the **int8 serving suite** (quantized bucket engine vs the f32
//! packed path at the acceptance shape), and the **parallel-tree
//! suite** (P trees at depth d − log2 P vs the single tree at depth d),
//! all recorded to `BENCH_gemm.json` (schema v7) so the perf trajectory
//! is tracked PR over PR:
//!
//! ```text
//! cargo bench --manifest-path rust/Cargo.toml --bench bench_micro          # full, from repo root
//! cargo bench --bench bench_micro -- --quick                               # CI smoke subset
//! cargo bench --bench bench_micro -- --quick --routing-only                # descent smoke only
//! cargo bench --bench bench_micro -- --quick --train-only                  # training smoke only
//! cargo bench --bench bench_micro -- --quick --quant-only                  # int8 smoke only
//! cargo bench --bench bench_micro -- --quick --parallel-only               # P-tree smoke only
//! ```

use fastfeedforward::bench::{time_budgeted, time_fn, Table};
use fastfeedforward::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig, NativeFffBackend};
use fastfeedforward::nn::loss::cross_entropy_into;
use fastfeedforward::nn::{Ff, Fff, FffConfig, FffInfer, InferScratch, Model};
use fastfeedforward::rng::Rng;
use fastfeedforward::tensor::kernels::relu_store;
use fastfeedforward::tensor::{gemm, gemm_bias_relu, gemm_scalar, kernels, pool, Matrix};
use std::time::Duration;

/// Thread counts the scaling suite sweeps.
const THREAD_SWEEP: [usize; 4] = [1, 2, 4, 8];

/// Thread counts the routing suite sweeps (ISSUE 2 acceptance grid).
const ROUTE_THREAD_SWEEP: [usize; 3] = [1, 2, 4];

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.4}")
    } else {
        "null".to_string()
    }
}

/// Routing-descent scaling suite: the batched level-synchronous router
/// ([`FffInfer::route_batch`]) vs the per-sample descent, depths 4–15 at
/// 1/2/4 threads, in the descent-dominated regime (`leaf ≤ 8`). Returns
/// the `routing` rows for `BENCH_gemm.json`.
fn routing_suite(quick: bool) -> Vec<String> {
    let mut table = Table::new("routing descent scaling", &["name", "time", "derived"]);
    let mut rows: Vec<String> = Vec::new();
    let budget = Duration::from_millis(if quick { 120 } else { 400 });
    let (dim_in, leaf) = (128usize, 4usize);
    let batch = if quick { 1024 } else { 4096 };
    // Leaf storage is aliased to 64 banks so deep trees stay routing
    // benchmarks, not allocation benchmarks; descent work is exact.
    let depths: &[usize] = if quick { &[4, 11] } else { &[4, 8, 12, 15] };
    for &depth in depths {
        let mut rng = Rng::seed_from_u64(21);
        let model = FffInfer::random(&mut rng, dim_in, 16, depth, leaf, 64);
        let mut x = Matrix::zeros(batch, dim_in);
        rng.fill_normal(x.as_mut_slice(), 0.0, 1.0);
        // Baseline: the dependent per-sample walk, single thread.
        pool::set_global_threads(1);
        let t_per_sample = time_budgeted(budget, 3, 1000, || {
            let mut acc = 0usize;
            for r in 0..batch {
                acc ^= model.route(x.row(r));
            }
            std::hint::black_box(acc);
        });
        let us = t_per_sample.mean_us();
        table.row(vec![
            format!("route d={depth} b={batch} per-sample"),
            format!("{:.3} ms", t_per_sample.mean_ms()),
            format!("{:.0} samples/ms", batch as f64 / t_per_sample.mean_ms()),
        ]);
        rows.push(format!(
            "{{\"depth\": {depth}, \"dim_in\": {dim_in}, \"batch\": {batch}, \
             \"path\": \"per-sample\", \"threads\": 1, \"ms\": {}, \"us_per_sample\": {}, \
             \"speedup_vs_per_sample\": 1.0}}",
            json_num(t_per_sample.mean_ms()),
            json_num(us / batch as f64),
        ));
        for &threads in &ROUTE_THREAD_SWEEP {
            pool::set_global_threads(threads);
            let t = time_budgeted(budget, 3, 1000, || {
                std::hint::black_box(model.route_batch(&x));
            });
            let speedup = t_per_sample.mean.as_secs_f64() / t.mean.as_secs_f64();
            table.row(vec![
                format!("route_batch d={depth} b={batch} t={threads}"),
                format!("{:.3} ms", t.mean_ms()),
                format!("{speedup:.2}x vs per-sample"),
            ]);
            rows.push(format!(
                "{{\"depth\": {depth}, \"dim_in\": {dim_in}, \"batch\": {batch}, \
                 \"path\": \"batched\", \"threads\": {threads}, \"ms\": {}, \
                 \"us_per_sample\": {}, \"speedup_vs_per_sample\": {}}}",
                json_num(t.mean_ms()),
                json_num(t.mean_us() / batch as f64),
                json_num(speedup),
            ));
        }
    }
    pool::set_global_threads(pool::default_global_threads());
    table.print();
    rows
}

/// Fused-vs-separate epilogue suite: `gemm_bias_relu` (bias+ReLU in the
/// store phase) against `gemm` + an elementwise bias/ReLU pass, on a
/// square shape and the thin-`k` leaf-GEMM shape where the saved passes
/// matter. Returns the `epilogue` rows for `BENCH_gemm.json`.
fn epilogue_suite(quick: bool) -> Vec<String> {
    let mut table = Table::new("fused vs separate epilogue", &["name", "time", "derived"]);
    let mut rows: Vec<String> = Vec::new();
    let budget = Duration::from_millis(if quick { 120 } else { 400 });
    let shapes: &[(usize, usize, usize)] =
        if quick { &[(512, 16, 256)] } else { &[(256, 256, 256), (4096, 16, 256)] };
    // Zero threshold so the labelled kernel really runs at every shape;
    // guard restores it (and clears the forced kind) on exit.
    let _guard = fastfeedforward::testing::KernelStateGuard::zero_threshold();
    let isa = kernels::table().isa;
    for &(m, k, n) in shapes {
        let mut rng = Rng::seed_from_u64(99);
        let mut a = Matrix::zeros(m, k);
        let mut b = Matrix::zeros(k, n);
        let mut bias = vec![0.0f32; n];
        rng.fill_normal(a.as_mut_slice(), 0.0, 1.0);
        rng.fill_normal(b.as_mut_slice(), 0.0, 1.0);
        rng.fill_normal(&mut bias, 0.0, 1.0);
        kernels::force(Some(kernels::KernelKind::Packed));
        for &threads in &[1usize, 2] {
            pool::set_global_threads(threads);
            let t_unfused = time_budgeted(budget, 3, 1000, || {
                let mut c = gemm(&a, &b);
                for r in 0..c.rows() {
                    for (j, v) in c.row_mut(r).iter_mut().enumerate() {
                        *v = relu_store(*v + bias[j]);
                    }
                }
                std::hint::black_box(c);
            });
            let t_fused = time_budgeted(budget, 3, 1000, || {
                std::hint::black_box(gemm_bias_relu(&a, &b, &bias));
            });
            let speedup = t_unfused.mean.as_secs_f64() / t_fused.mean.as_secs_f64();
            table.row(vec![
                format!("bias_relu {m}x{k}x{n} t={threads} fused"),
                format!("{:.3} ms", t_fused.mean_ms()),
                format!("{speedup:.2}x vs separate pass ({:.3} ms)", t_unfused.mean_ms()),
            ]);
            for (fused, t) in [(false, &t_unfused), (true, &t_fused)] {
                rows.push(format!(
                    "{{\"shape\": \"{m}x{k}x{n}\", \"epilogue\": \"bias_relu\", \
                     \"fused\": {fused}, \"kernel\": \"packed\", \"isa\": \"{isa}\", \
                     \"threads\": {threads}, \"ms\": {}, \"speedup_vs_unfused\": {}}}",
                    json_num(t.mean_ms()),
                    json_num(if fused { speedup } else { 1.0 }),
                ));
            }
        }
        kernels::force(None);
    }
    pool::set_global_threads(pool::default_global_threads());
    table.print();
    rows
}

/// Scratch-arena suite: steady-state batched serving with retained
/// [`InferScratch`]/output (arena on) against the allocating wrappers
/// (arena off), batch 4096 at depth 8 — the ISSUE-4 acceptance shape.
/// Both sides share one precomputed descent so the rows isolate the
/// bucket-engine cost. Returns the `scratch` rows for `BENCH_gemm.json`.
fn scratch_suite(quick: bool) -> Vec<String> {
    let mut table = Table::new("scratch arena on/off", &["name", "time", "derived"]);
    let mut rows: Vec<String> = Vec::new();
    let budget = Duration::from_millis(if quick { 120 } else { 400 });
    let (dim_in, dim_out, leaf) = (256usize, 256usize, 16usize);
    let (depth, batch) = if quick { (6usize, 1024usize) } else { (8usize, 4096usize) };
    let mut rng = Rng::seed_from_u64(17);
    let model = FffInfer::random(&mut rng, dim_in, dim_out, depth, leaf, 1 << depth);
    let mut x = Matrix::zeros(batch, dim_in);
    rng.fill_normal(x.as_mut_slice(), 0.0, 1.0);
    let leaf_of = model.route_batch(&x);
    for &threads in &[1usize, 2, 4] {
        pool::set_global_threads(threads);
        let t_alloc = time_budgeted(budget, 3, 1000, || {
            std::hint::black_box(model.infer_batch_routed(&x, &leaf_of));
        });
        let mut scratch = InferScratch::new();
        let mut y = Matrix::zeros(0, 0);
        let t_arena = time_budgeted(budget, 3, 1000, || {
            model.infer_batch_routed_into(&x, &leaf_of, &mut scratch, &mut y);
            std::hint::black_box(&y);
        });
        let speedup = t_alloc.mean.as_secs_f64() / t_arena.mean.as_secs_f64();
        table.row(vec![
            format!("serve d={depth} l={leaf} b={batch} t={threads} arena"),
            format!("{:.3} ms", t_arena.mean_ms()),
            format!("{speedup:.2}x vs allocating ({:.3} ms)", t_alloc.mean_ms()),
        ]);
        for (arena, t) in [(false, &t_alloc), (true, &t_arena)] {
            rows.push(format!(
                "{{\"depth\": {depth}, \"leaf\": {leaf}, \"dim\": {dim_in}, \
                 \"batch\": {batch}, \"arena\": {arena}, \"threads\": {threads}, \
                 \"ms\": {}, \"samples_per_ms\": {}, \"speedup_vs_alloc\": {}}}",
                json_num(t.mean_ms()),
                json_num(batch as f64 / t.mean_ms()),
                json_num(if arena { speedup } else { 1.0 }),
            ));
        }
    }
    pool::set_global_threads(pool::default_global_threads());
    table.print();
    rows
}

/// Training-engine suite: the level-batched GEMM training step
/// (forward `FORWARD_T`, cross-entropy gradient, backward) against the
/// per-node baseline engine, on the Table-2-shaped workload (dim ≥ 128,
/// depth ≥ 8, batch 4096; ISSUE 5 acceptance: ≥ 2x single-thread and
/// scaling at 2+ threads). Returns the `train` rows for
/// `BENCH_gemm.json`.
fn train_suite(quick: bool) -> Vec<String> {
    let mut table = Table::new("training engine scaling", &["name", "time", "derived"]);
    let mut rows: Vec<String> = Vec::new();
    let budget = Duration::from_millis(if quick { 150 } else { 600 });
    let (dim_in, dim_out, leaf) = (if quick { 128usize } else { 256 }, 10usize, 4usize);
    let (depth, batch) = if quick { (5usize, 1024usize) } else { (8usize, 4096usize) };
    let mut rng = Rng::seed_from_u64(33);
    let mut cfg = FffConfig::new(dim_in, dim_out, depth, leaf);
    cfg.hardening = 3.0;
    let mut model = Fff::new(&mut rng, cfg);
    let mut x = Matrix::zeros(batch, dim_in);
    rng.fill_normal(x.as_mut_slice(), 0.0, 1.0);
    let labels: Vec<usize> = (0..batch).map(|r| r % dim_out).collect();
    let mut logits = Matrix::zeros(0, 0);
    let mut dl = Matrix::zeros(0, 0);
    let mut dx = Matrix::zeros(0, 0);

    // Baseline: the per-node reference engine, single thread (what every
    // pre-PR-5 Table 2 epoch ran).
    pool::set_global_threads(1);
    let mut brng = Rng::seed_from_u64(5);
    let t_base = time_budgeted(budget, 2, 200, || {
        let y = model.forward_train_baseline(&x, &mut brng);
        std::hint::black_box(cross_entropy_into(&y, &labels, &mut dl));
        model.zero_grad();
        std::hint::black_box(model.backward_baseline(&dl));
    });
    table.row(vec![
        format!("train step d={depth} dim={dim_in} b={batch} per-node"),
        format!("{:.3} ms", t_base.mean_ms()),
        format!("{:.0} samples/ms", batch as f64 / t_base.mean_ms()),
    ]);
    rows.push(format!(
        "{{\"depth\": {depth}, \"dim\": {dim_in}, \"leaf\": {leaf}, \"batch\": {batch}, \
         \"path\": \"per-node\", \"threads\": 1, \"ms\": {}, \"samples_per_ms\": {}, \
         \"speedup_vs_per_node\": 1.0}}",
        json_num(t_base.mean_ms()),
        json_num(batch as f64 / t_base.mean_ms()),
    ));
    for &threads in &ROUTE_THREAD_SWEEP {
        pool::set_global_threads(threads);
        let mut srng = Rng::seed_from_u64(5);
        let t = time_budgeted(budget, 2, 200, || {
            model.forward_train_into(&x, &mut srng, &mut logits);
            std::hint::black_box(cross_entropy_into(&logits, &labels, &mut dl));
            model.zero_grad();
            model.backward_into(&dl, &mut dx);
            std::hint::black_box(&dx);
        });
        let speedup = t_base.mean.as_secs_f64() / t.mean.as_secs_f64();
        table.row(vec![
            format!("train step d={depth} dim={dim_in} b={batch} level-batched t={threads}"),
            format!("{:.3} ms", t.mean_ms()),
            format!("{speedup:.2}x vs per-node"),
        ]);
        rows.push(format!(
            "{{\"depth\": {depth}, \"dim\": {dim_in}, \"leaf\": {leaf}, \"batch\": {batch}, \
             \"path\": \"level-batched\", \"threads\": {threads}, \"ms\": {}, \
             \"samples_per_ms\": {}, \"speedup_vs_per_node\": {}}}",
            json_num(t.mean_ms()),
            json_num(batch as f64 / t.mean_ms()),
            json_num(speedup),
        ));
    }
    pool::set_global_threads(pool::default_global_threads());
    table.print();
    rows
}

/// Int8 serving suite (§Perf iteration 6): the quantized bucket engine
/// against the f32 packed path at the ISSUE-6 acceptance shape (dim
/// 256, depth 8, leaf 16, batch 4096) plus the scalar int8 replica for
/// the record, at 1/2 threads. Both models draw one weight stream from
/// one seed, so the comparison is served-bits-for-served-bits on
/// identical routing. The committed `BENCH_gemm.json` rows follow the
/// C-prototype convention (no in-container Rust toolchain); CI
/// regenerates the Rust numbers with this suite. Returns the `quant`
/// rows for `BENCH_gemm.json`.
fn quant_suite(quick: bool) -> Vec<String> {
    use fastfeedforward::tensor::Precision;
    let mut table = Table::new("int8 vs f32 serving", &["name", "time", "derived"]);
    let mut rows: Vec<String> = Vec::new();
    let budget = Duration::from_millis(if quick { 150 } else { 600 });
    let (dim, depth, leaf) = (256usize, 8usize, 16usize);
    let batch = if quick { 512 } else { 4096 };
    // Same seed → same weight stream → identical routing; only the
    // serving arithmetic differs between the two compiles.
    let mut rng = Rng::seed_from_u64(27);
    let mf32 =
        FffInfer::random_with(&mut rng, dim, dim, depth, leaf, 1 << depth, Precision::F32);
    let mut rng = Rng::seed_from_u64(27);
    let mi8 =
        FffInfer::random_with(&mut rng, dim, dim, depth, leaf, 1 << depth, Precision::Int8);
    let mut x = Matrix::zeros(batch, dim);
    rng.fill_normal(x.as_mut_slice(), 0.0, 1.0);
    let leaf_of = mf32.route_batch(&x);
    let i8_isa = kernels::active_i8().label;
    for &threads in &[1usize, 2] {
        pool::set_global_threads(threads);
        let mut scratch = InferScratch::new();
        let mut y = Matrix::zeros(0, 0);
        let t_f32 = time_budgeted(budget, 3, 1000, || {
            mf32.infer_batch_routed_into(&x, &leaf_of, &mut scratch, &mut y);
            std::hint::black_box(&y);
        });
        let t_i8 = time_budgeted(budget, 3, 1000, || {
            mi8.infer_batch_routed_into(&x, &leaf_of, &mut scratch, &mut y);
            std::hint::black_box(&y);
        });
        let speedup = t_f32.mean.as_secs_f64() / t_i8.mean.as_secs_f64();
        table.row(vec![
            format!("serve d={depth} dim={dim} b={batch} t={threads} f32-packed"),
            format!("{:.3} ms", t_f32.mean_ms()),
            format!("{:.0} samples/ms", batch as f64 / t_f32.mean_ms()),
        ]);
        table.row(vec![
            format!("serve d={depth} dim={dim} b={batch} t={threads} int8[{i8_isa}]"),
            format!("{:.3} ms", t_i8.mean_ms()),
            format!("{speedup:.2}x vs f32 packed"),
        ]);
        for (precision, isa, t, s) in
            [("f32", "packed", &t_f32, 1.0), ("int8", i8_isa, &t_i8, speedup)]
        {
            rows.push(format!(
                "{{\"dim\": {dim}, \"depth\": {depth}, \"leaf\": {leaf}, \"batch\": {batch}, \
                 \"precision\": \"{precision}\", \"kernel\": \"{isa}\", \"threads\": {threads}, \
                 \"ms\": {}, \"samples_per_ms\": {}, \"speedup_vs_f32\": {}}}",
                json_num(t.mean_ms()),
                json_num(batch as f64 / t.mean_ms()),
                json_num(s),
            ));
        }
    }
    pool::set_global_threads(pool::default_global_threads());
    table.print();
    rows
}

/// Parallel-tree suite (§Perf iteration 8): `P` trees at depth
/// `d − log2(P)` and leaf width `ℓ/P` against the single tree at depth
/// `d`, leaf `ℓ` — same total bank count (`P·2^(d−log2 P) = 2^d`) and
/// same summed active width, so the row measures what the multi-tree
/// machinery itself (P shorter descents, (tree, leaf) buckets,
/// scatter-add accumulation) costs over one scatter at the ISSUE-8
/// acceptance shape (dim 256, ℓ 16, batch 4096; P=2 must stay within
/// 1.3x of the single tree).
/// The committed `BENCH_gemm.json` rows follow the C-prototype
/// convention (no in-container Rust toolchain); CI regenerates the
/// Rust numbers with this suite. Returns the `parallel` rows for
/// `BENCH_gemm.json`.
fn parallel_suite(quick: bool) -> Vec<String> {
    use fastfeedforward::tensor::Precision;
    let mut table = Table::new("parallel trees vs single tree", &["name", "time", "derived"]);
    let mut rows: Vec<String> = Vec::new();
    let budget = Duration::from_millis(if quick { 150 } else { 600 });
    let (dim, leaf) = (256usize, 16usize);
    let depth = if quick { 6usize } else { 8 };
    let batch = if quick { 512 } else { 4096 };
    let mut x = Matrix::zeros(batch, dim);
    let mut xrng = Rng::seed_from_u64(82);
    xrng.fill_normal(x.as_mut_slice(), 0.0, 1.0);
    for &threads in &[1usize, 2] {
        pool::set_global_threads(threads);
        let mut scratch = InferScratch::new();
        let mut y = Matrix::zeros(0, 0);
        let mut t_single = 0.0f64;
        // P=1 at depth d, then each P at depth d − log2(P) with leaf
        // width ℓ/P: every configuration serves 2^d banks and ℓ summed
        // active neurons per sample, so the delta is the multi-tree
        // machinery itself.
        for p in [1usize, 2, 4] {
            let d = depth - p.trailing_zeros() as usize;
            let lf = leaf / p;
            let mut rng = Rng::seed_from_u64(81);
            let model =
                FffInfer::random_p(&mut rng, dim, dim, d, lf, 1 << d, Precision::F32, p);
            let leaf_of = model.route_batch(&x);
            let t = time_budgeted(budget, 3, 1000, || {
                model.infer_batch_routed_into(&x, &leaf_of, &mut scratch, &mut y);
                std::hint::black_box(&y);
            });
            if p == 1 {
                t_single = t.mean.as_secs_f64();
            }
            let cost = t.mean.as_secs_f64() / t_single;
            table.row(vec![
                format!("serve P={p} d={d} l={lf} dim={dim} b={batch} t={threads}"),
                format!("{:.3} ms", t.mean_ms()),
                format!("{cost:.2}x vs P=1 d={depth} l={leaf}"),
            ]);
            rows.push(format!(
                "{{\"dim\": {dim}, \"depth\": {d}, \"leaf\": {lf}, \"batch\": {batch}, \
                 \"trees\": {p}, \"threads\": {threads}, \"ms\": {}, \
                 \"samples_per_ms\": {}, \"cost_vs_single\": {}}}",
                json_num(t.mean_ms()),
                json_num(batch as f64 / t.mean_ms()),
                json_num(cost),
            ));
        }
    }
    pool::set_global_threads(pool::default_global_threads());
    table.print();
    rows
}

/// GEMM + FFF-inference thread-scaling suite → `BENCH_gemm.json`.
fn scaling_suite(quick: bool) {
    let mut table = Table::new("gemm/fff_infer scaling", &["name", "time", "derived"]);
    let mut gemm_rows: Vec<String> = Vec::new();
    let mut fff_rows: Vec<String> = Vec::new();
    let budget = Duration::from_millis(if quick { 120 } else { 400 });

    let shapes: &[(usize, usize, usize)] = if quick {
        &[(64, 64, 64), (256, 256, 256)]
    } else {
        &[(64, 64, 64), (128, 128, 128), (256, 256, 256), (512, 512, 512)]
    };
    // Microkernel ISA for row labels ("avx2-fma", "avx", "neon",
    // "portable"); the banded/serial kernels are compiler-auto-vectorized.
    let packed_isa = kernels::table().isa;
    // Zero the FLOP threshold for the sweep so rows labelled
    // packed/banded really run that kernel even at 64³ (small shapes
    // then include the dispatch overhead they would dodge in production,
    // which is the honest number for a kernel-labelled row). The guard
    // restores the threshold (and clears any forced kernel) when the
    // sweep scope ends, panic included.
    let threshold_guard = fastfeedforward::testing::KernelStateGuard::zero_threshold();
    for &(m, k, n) in shapes {
        let mut rng = Rng::seed_from_u64(42);
        let mut a = Matrix::zeros(m, k);
        let mut b = Matrix::zeros(k, n);
        rng.fill_normal(a.as_mut_slice(), 0.0, 1.0);
        rng.fill_normal(b.as_mut_slice(), 0.0, 1.0);
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        // Baseline: the seed's serial kernel (what `serial` forces).
        let t_serial = time_budgeted(budget, 3, 1000, || {
            std::hint::black_box(gemm_scalar(&a, &b));
        });
        table.row(vec![
            format!("gemm {m}x{k}x{n} serial(seed)"),
            format!("{:.3} ms", t_serial.mean_ms()),
            format!("{:.2} GFLOP/s", flops / t_serial.mean.as_secs_f64() / 1e9),
        ]);
        gemm_rows.push(format!(
            "{{\"shape\": \"{m}x{k}x{n}\", \"kernel\": \"serial\", \"isa\": \"autovec\", \
             \"threads\": 1, \"ms\": {}, \"gflops\": {}, \"speedup_vs_serial\": 1.0}}",
            json_num(t_serial.mean_ms()),
            json_num(flops / t_serial.mean.as_secs_f64() / 1e9),
        ));
        for kind in [kernels::KernelKind::Packed, kernels::KernelKind::Banded] {
            kernels::force(Some(kind));
            let isa = match kind {
                kernels::KernelKind::Packed => packed_isa,
                _ => "autovec",
            };
            for &threads in &THREAD_SWEEP {
                pool::set_global_threads(threads);
                let t = time_budgeted(budget, 3, 1000, || {
                    std::hint::black_box(gemm(&a, &b));
                });
                let speedup = t_serial.mean.as_secs_f64() / t.mean.as_secs_f64();
                table.row(vec![
                    format!("gemm {m}x{k}x{n} {}[{isa}] t={threads}", kind.name()),
                    format!("{:.3} ms", t.mean_ms()),
                    format!(
                        "{:.2} GFLOP/s, {speedup:.2}x vs serial",
                        flops / t.mean.as_secs_f64() / 1e9
                    ),
                ]);
                gemm_rows.push(format!(
                    "{{\"shape\": \"{m}x{k}x{n}\", \"kernel\": \"{}\", \"isa\": \"{isa}\", \
                     \"threads\": {threads}, \"ms\": {}, \"gflops\": {}, \
                     \"speedup_vs_serial\": {}}}",
                    kind.name(),
                    json_num(t.mean_ms()),
                    json_num(flops / t.mean.as_secs_f64() / 1e9),
                    json_num(speedup),
                ));
            }
            kernels::force(None);
        }
    }
    // The fff_infer suite below measures production dispatch, so the
    // threshold goes back to its real value here.
    drop(threshold_guard);

    // FFF batched inference: leaf-bucketed grouped path vs the per-sample
    // loop, across the same thread sweep (fixed seed, skewed-free random
    // routing; depth 8 → 256 leaves).
    let (dim_in, dim_out, depth, leaf) = (256usize, 256usize, 8usize, 16usize);
    let batch = if quick { 512 } else { 2048 };
    let mut rng = Rng::seed_from_u64(7);
    let model = FffInfer::random(&mut rng, dim_in, dim_out, depth, leaf, 1 << depth);
    let mut x = Matrix::zeros(batch, dim_in);
    rng.fill_normal(x.as_mut_slice(), 0.0, 1.0);
    let t_per_sample = time_budgeted(budget, 3, 1000, || {
        let mut y = Matrix::zeros(batch, dim_out);
        for r in 0..batch {
            model.infer_one(x.row(r), y.row_mut(r));
        }
        std::hint::black_box(y);
    });
    table.row(vec![
        format!("fff_infer d={depth} l={leaf} b={batch} per-sample"),
        format!("{:.3} ms", t_per_sample.mean_ms()),
        format!("{:.2} us/sample", t_per_sample.mean_us() / batch as f64),
    ]);
    fff_rows.push(format!(
        "{{\"depth\": {depth}, \"leaf\": {leaf}, \"batch\": {batch}, \"path\": \"per-sample\", \
         \"threads\": 1, \"ms\": {}, \"speedup_vs_per_sample\": 1.0}}",
        json_num(t_per_sample.mean_ms()),
    ));
    for &threads in &THREAD_SWEEP {
        pool::set_global_threads(threads);
        let t = time_budgeted(budget, 3, 1000, || {
            std::hint::black_box(model.infer_batch_grouped(&x));
        });
        let speedup = t_per_sample.mean.as_secs_f64() / t.mean.as_secs_f64();
        table.row(vec![
            format!("fff_infer d={depth} l={leaf} b={batch} grouped t={threads}"),
            format!("{:.3} ms", t.mean_ms()),
            format!("{speedup:.2}x vs per-sample"),
        ]);
        fff_rows.push(format!(
            "{{\"depth\": {depth}, \"leaf\": {leaf}, \"batch\": {batch}, \"path\": \"grouped\", \
             \"threads\": {threads}, \"ms\": {}, \"speedup_vs_per_sample\": {}}}",
            json_num(t.mean_ms()),
            json_num(speedup),
        ));
    }
    // Back to the default-sized pool (honors FFF_THREADS) for the rest.
    pool::set_global_threads(pool::default_global_threads());
    table.print();

    let epilogue_rows = epilogue_suite(quick);
    let scratch_rows = scratch_suite(quick);
    let routing_rows = routing_suite(quick);
    let train_rows = train_suite(quick);
    let quant_rows = quant_suite(quick);
    let parallel_rows = parallel_suite(quick);

    let out_path = std::env::var("FFF_BENCH_GEMM_OUT").unwrap_or_else(|_| "BENCH_gemm.json".into());
    let json = format!(
        "{{\n  \"schema\": \"fff-bench-gemm/v7\",\n  \"quick\": {quick},\n  \
         \"host_threads\": {},\n  \"isa\": \"{packed_isa}\",\n  \"gemm\": [\n    {}\n  ],\n  \
         \"fff_infer\": [\n    {}\n  ],\n  \"epilogue\": [\n    {}\n  ],\n  \
         \"scratch\": [\n    {}\n  ],\n  \"routing\": [\n    {}\n  ],\n  \
         \"train\": [\n    {}\n  ],\n  \"quant\": [\n    {}\n  ],\n  \
         \"parallel\": [\n    {}\n  ]\n}}\n",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        gemm_rows.join(",\n    "),
        fff_rows.join(",\n    "),
        epilogue_rows.join(",\n    "),
        scratch_rows.join(",\n    "),
        routing_rows.join(",\n    "),
        train_rows.join(",\n    "),
        quant_rows.join(",\n    "),
        parallel_rows.join(",\n    "),
    );
    match std::fs::write(&out_path, json) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("could not write {out_path}: {e}"),
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // Routing-only / train-only smokes: run just that suite (no JSON
    // rewrite, so a partial run never clobbers the tracked artifact).
    if std::env::args().any(|a| a == "--routing-only") {
        let _ = routing_suite(quick);
        return;
    }
    if std::env::args().any(|a| a == "--train-only") {
        let _ = train_suite(quick);
        return;
    }
    if std::env::args().any(|a| a == "--quant-only") {
        let _ = quant_suite(quick);
        return;
    }
    if std::env::args().any(|a| a == "--parallel-only") {
        let _ = parallel_suite(quick);
        return;
    }
    scaling_suite(quick);
    if quick {
        return;
    }
    let mut table = Table::new("micro-benchmarks", &["name", "time", "derived"]);
    let mut rng = Rng::seed_from_u64(0);

    // GEMM peaks (the FF baseline's engine).
    for &(m, k, n) in &[(256usize, 768usize, 768usize), (256, 784, 128), (2048, 768, 32)] {
        let mut a = Matrix::zeros(m, k);
        let mut b = Matrix::zeros(k, n);
        rng.fill_normal(a.as_mut_slice(), 0.0, 1.0);
        rng.fill_normal(b.as_mut_slice(), 0.0, 1.0);
        let t = time_budgeted(Duration::from_millis(500), 5, 1000, || {
            std::hint::black_box(gemm(&a, &b));
        });
        let flops = 2.0 * m as f64 * k as f64 * n as f64;
        table.row(vec![
            format!("gemm {m}x{k}x{n}"),
            format!("{:.3} ms", t.mean_ms()),
            format!("{:.2} GFLOP/s", flops / t.mean.as_secs_f64() / 1e9),
        ]);
    }

    // FFF routing descent only (the O(d) mechanism).
    for &depth in &[4usize, 8, 12] {
        let inf = FffInfer::random(&mut rng, 768, 768, depth, 32, 1 << 10);
        let xs: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..768).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let t = time_budgeted(Duration::from_millis(300), 5, 100_000, || {
            for x in &xs {
                std::hint::black_box(inf.route(x));
            }
        });
        table.row(vec![
            format!("fff route d={depth} (64 samples)"),
            format!("{:.1} us", t.mean_us()),
            format!("{:.2} us/sample", t.mean_us() / 64.0),
        ]);
    }

    // Single-sample leaf inference (serving hot path).
    {
        let inf = FffInfer::random(&mut rng, 784, 10, 4, 8, 16);
        let x: Vec<f32> = (0..784).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut out = vec![0.0f32; 10];
        let t = time_budgeted(Duration::from_millis(300), 100, 1_000_000, || {
            inf.infer_one(std::hint::black_box(&x), &mut out);
        });
        table.row(vec![
            "fff infer_one 784->10 (d=4 l=8)".into(),
            format!("{:.2} us", t.mean_us()),
            String::new(),
        ]);
    }

    // FF vs FFF batched inference at MNIST dims (quickstart's comparison).
    {
        let ff = Ff::new(&mut rng, 784, 64, 10).compile_infer();
        let fff = FffInfer::random(&mut rng, 784, 10, 3, 8, 8);
        let mut x = Matrix::zeros(256, 784);
        rng.fill_normal(x.as_mut_slice(), 0.0, 1.0);
        let t_ff = time_fn(3, 30, || {
            std::hint::black_box(ff.infer_batch(&x));
        });
        let t_fff = time_fn(3, 30, || {
            std::hint::black_box(fff.infer_batch(&x));
        });
        table.row(vec![
            "ff w=64 batch 256 (784->10)".into(),
            format!("{:.3} ms", t_ff.mean_ms()),
            String::new(),
        ]);
        table.row(vec![
            "fff d=3 l=8 batch 256 (784->10)".into(),
            format!("{:.3} ms", t_fff.mean_ms()),
            format!("{:.2}x vs ff", t_ff.mean.as_secs_f64() / t_fff.mean.as_secs_f64()),
        ]);
    }

    // Coordinator batching overhead: submit->response with a tiny model.
    {
        let model = FffInfer::random(&mut rng, 16, 4, 2, 2, 4);
        let coord = Coordinator::start(
            CoordinatorConfig {
                batcher: BatcherConfig { max_batch: 32, max_delay: Duration::from_micros(100) },
                workers: 1,
                threads: 0,
                queue_capacity: 10_000,
                ..CoordinatorConfig::default()
            },
            move || Box::new(NativeFffBackend::new(model.clone())),
        )
        .expect("native backend start");
        let t = time_budgeted(Duration::from_millis(500), 20, 50_000, || {
            let rx = coord.submit(vec![0.1; 16]).unwrap();
            std::hint::black_box(rx.recv().unwrap());
        });
        table.row(vec![
            "coordinator round-trip (1 in flight)".into(),
            format!("{:.1} us", t.mean_us()),
            "incl. 100us batch deadline".into(),
        ]);
        coord.shutdown();
    }

    table.print();
}
