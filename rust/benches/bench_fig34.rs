//! `cargo bench --bench bench_fig34` — regenerates the paper's fig34
//! (FFF_SCALE=smoke|paper; see rust/src/experiments/fig34.rs).

fn main() {
    let scale = fastfeedforward::bench::Scale::from_env();
    println!("scale: {scale:?} (set FFF_SCALE=paper for the full grid)");
    let t0 = std::time::Instant::now();
    fastfeedforward::experiments::fig34::run(scale);
    println!("[bench_fig34] total wall time: {:.1}s", t0.elapsed().as_secs_f64());
}
